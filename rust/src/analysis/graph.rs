//! Whole-crate analysis passes: the lock graph (`lock-graph`) and the
//! cross-function atomics rule (`atomic-ordering`).
//!
//! PR 9's `lock-order` rule is intra-function: it sees `let g =
//! a.lock…(); b.lock…();` inside one body and nothing else.  The lock
//! graph closes the gap the MPSC-ring work will live in: it tracks
//! guard lifetimes per function, resolves intra-crate calls by function
//! name (call-graph-lite — every same-named function is a candidate
//! callee), propagates "acquires-while-holding" edges across files, and
//! then *derives* the lock hierarchy from the edges.  The declared
//! `engine → router-lanes → metrics → health` order stops being an
//! assumption and becomes an assertion the derived graph must satisfy:
//! a cross-file inversion or a cycle is a finding even though no single
//! function ever nests two acquisitions.
//!
//! `atomic-ordering` is the same idea for atomics: a `Relaxed` publish
//! (store/swap/fetch_*) whose field is loaded to gate control flow in a
//! *different* function cannot synchronize anything — the load may
//! never observe the store in any useful happens-before sense.  Either
//! the pair is upgraded to `Release`/`Acquire`, or the field is a
//! monotonic counter and belongs in [`RELAXED_COUNTERS`], or the load
//! site carries a justified pragma (the power-of-two-choices sampler in
//! `serve/cluster` is the canonical intentional race).

use super::rules::{self, DECLARED_ORDER};
use super::sanitize::Sanitized;
use super::tokens::{TokKind, Tokens};
use super::Finding;
use std::collections::{BTreeMap, BTreeSet};

/// One sanitized + lexed file, borrowed by the crate passes.
pub struct FileView<'a> {
    pub path: &'a str,
    pub s: &'a Sanitized,
    pub t: &'a Tokens,
}

/// Fields allowed to stay `Relaxed` on the publish side even though
/// another function gates on their value: monotonic gauges/counters
/// whose *exact* value never carries a cross-thread protocol.  Each
/// entry is annotated — the justification prints in `--list-rules` and
/// the README, mirroring the pragma discipline.
pub const RELAXED_COUNTERS: &[(&str, &str)] = &[
    (
        "inflight",
        "per-replica in-flight gauge; read racily by power-of-two-choices \
         sampling (the load pair carries its own pragma in cluster::pick_replica)",
    ),
    (
        "in_flight",
        "pool work gauge; increment is Relaxed (the submit itself orders via the \
         queue mutex), decrement/read are Release/Acquire for drain()",
    ),
    (
        "tries",
        "per-replica dispatch counter; read only for reports and tests, never to \
         gate a cross-thread decision",
    ),
    (
        "next_id",
        "monotonic id allocator; uniqueness needs atomicity, not ordering",
    ),
    (
        "next_conn",
        "monotonic connection-id allocator; uniqueness needs atomicity, not ordering",
    ),
];

fn relaxed_counter(field: &str) -> bool {
    RELAXED_COUNTERS.iter().any(|(n, _)| *n == field)
}

/// Method-call shape at ident token `i` (`.name(`): `(dot, open)`.
fn method_call(t: &Tokens, i: usize) -> Option<(usize, usize)> {
    if i == 0 || !t.is_punct(i - 1, ".") || !t.is_punct(i + 1, "(") {
        return None;
    }
    Some((i - 1, i + 1))
}

// ---------------------------------------------------------------------------
// Lock graph
// ---------------------------------------------------------------------------

/// One class-level edge: "some thread acquires `to` while holding
/// `from`", with the first site that creates it.  `via` is the callee
/// chain for propagated edges (`None` for an intra-function nesting).
#[derive(Debug, Clone)]
pub struct Edge {
    pub from: &'static str,
    pub to: &'static str,
    pub path: String,
    pub line: usize,
    pub via: Option<String>,
    /// Number of distinct sites inducing this class pair.
    pub count: usize,
}

/// The derived whole-crate lock graph.
pub struct LockGraph {
    pub edges: Vec<Edge>,
    /// Every lock class that appears in any acquisition, sorted by
    /// declared level then name.
    pub classes: Vec<&'static str>,
}

struct FnNode {
    name: String,
    file: usize,
    /// Classes acquired directly in this body.
    direct: BTreeSet<&'static str>,
    /// (held, acquired, line) — intra-function nestings.
    edges: Vec<(&'static str, &'static str, usize)>,
    /// (callee name, held classes at the call, line).
    calls: Vec<(String, Vec<&'static str>, usize)>,
}

/// Walk one function body, tracking guard lifetimes exactly like
/// `rules::lock_order` (bind-to-hold, `drop()` release, brace expiry),
/// and record direct edges plus call sites with the held set.
fn scan_fn(view: &FileView, file: usize, fx: usize) -> FnNode {
    let t = view.t;
    let f = &t.fns[fx];
    let mut node = FnNode {
        name: f.name.clone(),
        file,
        direct: BTreeSet::new(),
        edges: Vec::new(),
        calls: Vec::new(),
    };
    // Nested fn items own their tokens; skip their body ranges.
    let nested: Vec<(usize, usize)> = t
        .fns
        .iter()
        .enumerate()
        .filter(|&(i, g)| i != fx && g.open > f.open && g.close < f.close)
        .map(|(_, g)| (g.open, g.close))
        .collect();
    let mut depth: i32 = 0;
    let mut held: Vec<(String, &'static str, i32)> = Vec::new();
    let mut j = f.open + 1;
    while j < f.close {
        if let Some(&(_, close)) = nested.iter().find(|&&(o, _)| o == j) {
            j = close + 1;
            continue;
        }
        let Some(tok) = t.tok(j) else { break };
        match tok.kind {
            TokKind::Punct => {
                match tok.text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        held.retain(|&(_, _, d)| d <= depth);
                    }
                    _ => {}
                }
                j += 1;
                continue;
            }
            TokKind::Ident => {}
            _ => {
                j += 1;
                continue;
            }
        }
        let name = tok.text.as_str();
        // Explicit early release.
        if name == "drop" && t.is_punct(j + 1, "(") && t.is_punct(j + 3, ")") {
            let g = t.text(j + 2).to_string();
            held.retain(|(h, _, _)| *h != g);
            j += 1;
            continue;
        }
        if rules::is_acquire_ident(name) {
            if let Some((dot, open)) = method_call(t, j) {
                if let Some((close, _, nonblank)) = t.call_args(open) {
                    if !nonblank {
                        if let Some((_, class)) = t
                            .receiver_of(dot)
                            .and_then(|r| rules::classify(r, view.path))
                        {
                            node.direct.insert(class);
                            for &(_, hclass, _) in held.iter() {
                                if hclass != class {
                                    node.edges.push((hclass, class, t.line(dot)));
                                }
                            }
                            if let Some(g) = rules::binds_guard(t, dot, close) {
                                held.push((g, class, depth));
                            }
                        }
                        j += 1;
                        continue;
                    }
                }
            }
        }
        // Plain call site: `name(` not preceded by `fn`, not a keyword,
        // not an atomic op.  Method calls (`recv.name(`) count too —
        // resolution is by name.
        if t.is_punct(j + 1, "(")
            && !t.is_ident(j.wrapping_sub(1), "fn")
            && !rules::is_acquire_ident(name)
            && !rules::is_atomic_op(name)
            && !matches!(
                name,
                "if" | "while" | "match" | "for" | "loop" | "return" | "drop"
            )
        {
            let held_classes: Vec<&'static str> = {
                let mut hs: Vec<&'static str> = held.iter().map(|&(_, c, _)| c).collect();
                hs.sort_unstable();
                hs.dedup();
                hs
            };
            node.calls.push((name.to_string(), held_classes, t.line(j)));
        }
        j += 1;
    }
    node
}

/// Build the whole-crate lock graph: scan every function, run the
/// may-acquire fixpoint over the name-resolved call graph, and collapse
/// sites into class-level edges.
pub fn build_lock_graph(files: &[FileView]) -> LockGraph {
    let mut nodes: Vec<FnNode> = Vec::new();
    for (fi, v) in files.iter().enumerate() {
        for fx in 0..v.t.fns.len() {
            nodes.push(scan_fn(v, fi, fx));
        }
    }
    let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (i, n) in nodes.iter().enumerate() {
        by_name.entry(&n.name).or_default().push(i);
    }
    // may_acquire fixpoint: what can each function (transitively) lock?
    let mut may: Vec<BTreeSet<&'static str>> = nodes.iter().map(|n| n.direct.clone()).collect();
    loop {
        let mut changed = false;
        for i in 0..nodes.len() {
            let mut add: BTreeSet<&'static str> = BTreeSet::new();
            for (callee, _, _) in &nodes[i].calls {
                if let Some(targets) = by_name.get(callee.as_str()) {
                    for &ti in targets {
                        add.extend(may[ti].iter().copied());
                    }
                }
            }
            for c in add {
                changed |= may[i].insert(c);
            }
        }
        if !changed {
            break;
        }
    }
    // Collapse to class-level edges, keeping the first site per pair.
    // Direct and call-propagated edges are kept distinct so a propagated
    // inversion can never hide behind an existing (legal-looking) direct
    // edge with the same class pair.
    let mut edges: BTreeMap<(&'static str, &'static str, bool), Edge> = BTreeMap::new();
    let mut add_edge =
        |from: &'static str, to: &'static str, path: &str, line: usize, via: Option<String>| {
            edges
                .entry((from, to, via.is_some()))
                .and_modify(|e| e.count += 1)
                .or_insert(Edge {
                    from,
                    to,
                    path: path.to_string(),
                    line,
                    via,
                    count: 1,
                });
        };
    for n in &nodes {
        let path = files[n.file].path;
        for &(from, to, line) in &n.edges {
            add_edge(from, to, path, line, None);
        }
        for (callee, held, line) in &n.calls {
            if held.is_empty() {
                continue;
            }
            let Some(targets) = by_name.get(callee.as_str()) else {
                continue;
            };
            let mut acq: BTreeSet<&'static str> = BTreeSet::new();
            for &ti in targets {
                acq.extend(may[ti].iter().copied());
            }
            for &from in held {
                for &to in &acq {
                    if from != to {
                        add_edge(from, to, path, *line, Some(callee.clone()));
                    }
                }
            }
        }
    }
    let mut classes: BTreeSet<&'static str> = BTreeSet::new();
    for n in &nodes {
        classes.extend(n.direct.iter().copied());
    }
    let mut classes: Vec<&'static str> = classes.into_iter().collect();
    classes.sort_by_key(|c| (rules::class_level(c), *c));
    LockGraph {
        edges: edges.into_values().collect(),
        classes,
    }
}

fn reachable(edges: &[Edge], from: &str, to: &str) -> bool {
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    let mut stack = vec![from];
    while let Some(u) = stack.pop() {
        if u == to {
            return true;
        }
        if !seen.insert(u) {
            continue;
        }
        for e in edges {
            if e.from == u {
                stack.push(e.to);
            }
        }
    }
    false
}

/// Topological order of the derived graph's classes (declared-level
/// tie-break), or `None` when the graph has a cycle.
pub fn topo_order(g: &LockGraph) -> Option<Vec<&'static str>> {
    let mut indeg: BTreeMap<&'static str, usize> =
        g.classes.iter().map(|&c| (c, 0usize)).collect();
    for e in &g.edges {
        *indeg.entry(e.to).or_insert(0) += 1;
        indeg.entry(e.from).or_insert(0);
    }
    let mut order = Vec::new();
    let mut left: Vec<&'static str> = indeg.keys().copied().collect();
    while !left.is_empty() {
        let pick = left
            .iter()
            .copied()
            .filter(|c| indeg[c] == 0)
            .min_by_key(|&c| (rules::class_level(c), c))?;
        order.push(pick);
        left.retain(|&c| c != pick);
        for e in &g.edges {
            if e.from == pick {
                *indeg.get_mut(e.to).unwrap() -= 1;
            }
        }
    }
    Some(order)
}

/// The `lock-graph` crate rule: cross-function/cross-file inversions
/// (propagated edges that descend the declared hierarchy) and cycles.
/// Intra-function inversions stay `lock-order`'s findings — this rule
/// reports exactly what the per-function rule *cannot* see.
pub fn lock_graph(files: &[FileView], out: &mut Vec<Finding>) {
    let g = build_lock_graph(files);
    let mut reported: BTreeSet<(String, usize)> = BTreeSet::new();
    for e in &g.edges {
        let (fl, tl) = (rules::class_level(e.from), rules::class_level(e.to));
        if e.via.is_some() && fl > tl {
            let via = e.via.as_deref().unwrap_or("?");
            if reported.insert((e.path.clone(), e.line)) {
                out.push(Finding::new(
                    super::RULE_LOCK_GRAPH,
                    &e.path,
                    e.line,
                    format!(
                        "holding '{}' (level {fl}) while calling `{via}`, which \
                         (transitively) acquires '{}' (level {tl}); declared order \
                         is {DECLARED_ORDER}",
                        e.from, e.to
                    ),
                ));
            }
        }
    }
    for e in &g.edges {
        if reachable(&g.edges, e.to, e.from) && reported.insert((e.path.clone(), e.line)) {
            out.push(Finding::new(
                super::RULE_LOCK_GRAPH,
                &e.path,
                e.line,
                format!(
                    "lock edge '{}' → '{}' participates in a cycle ('{}' can reach \
                     '{}' through other acquisitions): a cross-thread deadlock is \
                     one unlucky interleaving away",
                    e.from, e.to, e.to, e.from
                ),
            ));
        }
    }
}

/// Text dump for `sonic lint --lock-graph`.
pub fn render_lock_graph(g: &LockGraph) -> String {
    let mut s = String::new();
    s.push_str(&format!("declared : {DECLARED_ORDER}\n"));
    match topo_order(g) {
        Some(order) => s.push_str(&format!("derived  : {}\n", order.join(" → "))),
        None => s.push_str("derived  : CYCLIC\n"),
    }
    s.push_str(&format!(
        "classes  : {}\nedges    :\n",
        g.classes.join(", ")
    ));
    for e in &g.edges {
        let via = e
            .via
            .as_deref()
            .map(|v| format!(" via `{v}`"))
            .unwrap_or_default();
        s.push_str(&format!(
            "  {} → {}  ({} site{}{}; first {}:{})\n",
            e.from,
            e.to,
            e.count,
            if e.count == 1 { "" } else { "s" },
            via,
            e.path,
            e.line
        ));
    }
    if g.edges.is_empty() {
        s.push_str("  (none — no nested acquisitions anywhere)\n");
    }
    s
}

// ---------------------------------------------------------------------------
// atomic-ordering
// ---------------------------------------------------------------------------

const ORDERINGS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

fn is_publish_op(name: &str) -> bool {
    matches!(
        name,
        "store"
            | "swap"
            | "fetch_add"
            | "fetch_sub"
            | "fetch_and"
            | "fetch_or"
            | "fetch_xor"
            | "fetch_nand"
            | "compare_exchange"
            | "compare_exchange_weak"
    )
}

/// First token index of the receiver chain ending at the `.` token
/// `dot` (e.g. `self.replicas[i].inflight.load` → the `self` token).
fn chain_start(t: &Tokens, dot: usize) -> usize {
    let mut d = dot;
    loop {
        if d == 0 {
            return 0;
        }
        let mut k = d - 1;
        if t.is_punct(k, ")") || t.is_punct(k, "]") {
            match t.match_of(k) {
                Some(o) if o > 0 => k = o - 1,
                _ => return d,
            }
        }
        let Some(tok) = t.tok(k) else { return d };
        if tok.kind != TokKind::Ident {
            return d;
        }
        if k > 0 && t.is_punct(k - 1, ".") {
            d = k - 1;
        } else {
            return k;
        }
    }
}

/// Is the load whose receiver chain starts at `start` and whose call
/// closes at `close` in a control-flow-gating position?  Three shapes:
/// inside an `if`/`while`/`match` condition span, negated (`!x.load`),
/// or comparison-adjacent (`x.load(..) >= n`, `n < x.load(..)`).
fn is_gating(t: &Tokens, start: usize, dot: usize, close: usize) -> bool {
    if t.in_gating_span(dot) {
        return true;
    }
    if start > 0 && t.is_punct(start - 1, "!") && !t.is_punct(start.wrapping_sub(2), "=") {
        return true;
    }
    let before_cmp = start > 0
        && (t.is_punct(start - 1, "<")
            || t.is_punct(start - 1, ">")
            || (t.is_punct(start - 1, "=")
                && start > 1
                && ["=", "!", "<", ">"].iter().any(|p| t.is_punct(start - 2, p))));
    let after_cmp = t.is_punct(close + 1, "<")
        || t.is_punct(close + 1, ">")
        || (t.is_punct(close + 1, "=") && t.is_punct(close + 2, "="))
        || (t.is_punct(close + 1, "!") && t.is_punct(close + 2, "="));
    before_cmp || after_cmp
}

struct AtomicSite {
    file: usize,
    line: usize,
    /// (file, fn body open token) — identity of the enclosing function.
    func: (usize, usize),
    op: String,
    relaxed: bool,
    gating: bool,
}

/// The `atomic-ordering` crate rule.  Per atomic field (receiver name),
/// collect publishes (store/swap/fetch_*/cas) and gating loads across
/// the whole crate; a `Relaxed` half of a cross-function publish →
/// gated-load pair is a finding on that half.
pub fn atomic_ordering(files: &[FileView], out: &mut Vec<Finding>) {
    let mut publishes: BTreeMap<String, Vec<AtomicSite>> = BTreeMap::new();
    let mut loads: BTreeMap<String, Vec<AtomicSite>> = BTreeMap::new();
    for (fi, v) in files.iter().enumerate() {
        let t = v.t;
        for i in 0..t.toks.len() {
            let Some(tok) = t.tok(i) else { continue };
            if tok.kind != TokKind::Ident {
                continue;
            }
            let name = tok.text.as_str();
            let is_load = name == "load";
            if !is_load && !is_publish_op(name) {
                continue;
            }
            let Some((dot, open)) = method_call(t, i) else {
                continue;
            };
            let Some((close, _, _)) = t.call_args(open) else {
                continue;
            };
            let mut ords: Vec<&str> = Vec::new();
            for j in open + 1..close {
                let txt = t.text(j);
                if ORDERINGS.contains(&txt) {
                    ords.push(if txt == "Relaxed" {
                        "Relaxed"
                    } else if txt == "Acquire" {
                        "Acquire"
                    } else if txt == "Release" {
                        "Release"
                    } else if txt == "AcqRel" {
                        "AcqRel"
                    } else {
                        "SeqCst"
                    });
                }
            }
            if ords.is_empty() {
                continue; // not an atomic access (no Ordering argument)
            }
            let Some(field) = t.receiver_of(dot).map(str::to_string) else {
                continue;
            };
            let func = (fi, t.fn_of(i).map(|f| f.open).unwrap_or(usize::MAX));
            let site = AtomicSite {
                file: fi,
                line: t.line(dot),
                func,
                op: name.to_string(),
                relaxed: ords.contains(&"Relaxed"),
                gating: is_load && is_gating(t, chain_start(t, dot), dot, close),
            };
            if is_load {
                loads.entry(field).or_default().push(site);
            } else {
                publishes.entry(field).or_default().push(site);
            }
        }
    }
    // Publish side: Relaxed publish observed (as a gate) elsewhere.
    for (field, pubs) in &publishes {
        if relaxed_counter(field) {
            continue;
        }
        let gates: Vec<&AtomicSite> = loads
            .get(field)
            .map(|ls| ls.iter().filter(|l| l.gating).collect())
            .unwrap_or_default();
        for p in pubs.iter().filter(|p| p.relaxed) {
            if let Some(g) = gates.iter().find(|g| g.func != p.func) {
                out.push(Finding::new(
                    super::RULE_ATOMIC_ORDERING,
                    files[p.file].path,
                    p.line,
                    format!(
                        "Relaxed `{}` on `{field}` publishes a value that gates \
                         control flow in another function ({}:{}); a Relaxed store \
                         synchronizes nothing — use Ordering::Release, list the \
                         field in RELAXED_COUNTERS, or justify with a pragma",
                        p.op, files[g.file].path, g.line
                    ),
                ));
            }
        }
    }
    // Load side: Relaxed gating load of a field published elsewhere.
    for (field, ls) in &loads {
        for l in ls.iter().filter(|l| l.gating && l.relaxed) {
            if let Some(p) = publishes
                .get(field)
                .and_then(|ps| ps.iter().find(|p| p.func != l.func))
            {
                out.push(Finding::new(
                    super::RULE_ATOMIC_ORDERING,
                    files[l.file].path,
                    l.line,
                    format!(
                        "Relaxed load of `{field}` gates control flow, but `{field}` \
                         is published in another function ({}:{}); the gate may never \
                         observe the write in a useful order — use Ordering::Acquire \
                         or justify the race with a pragma",
                        files[p.file].path, p.line
                    ),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::sanitize::sanitize;
    use super::super::tokens::lex;
    use super::super::Finding;
    use super::*;

    fn views(srcs: &[(&str, &str)]) -> Vec<(String, Sanitized, Tokens)> {
        srcs.iter()
            .map(|(p, src)| {
                let s = sanitize(src);
                let t = lex(&s);
                (p.to_string(), s, t)
            })
            .collect()
    }

    fn run(rule: fn(&[FileView], &mut Vec<Finding>), srcs: &[(&str, &str)]) -> Vec<Finding> {
        let owned = views(srcs);
        let fv: Vec<FileView> = owned
            .iter()
            .map(|(p, s, t)| FileView { path: p, s, t })
            .collect();
        let mut out = Vec::new();
        rule(&fv, &mut out);
        out
    }

    #[test]
    fn cross_file_inversion_is_found() {
        let a = "fn caller(s: &S) {\n    let c = s.counters.lock_or_recover();\n    helper(s);\n}\n";
        let b = "fn helper(s: &S) {\n    let q = s.queue.lock_or_recover();\n    q.push(1);\n}\n";
        let f = run(lock_graph, &[("a.rs", a), ("b.rs", b)]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].path, "a.rs");
        assert_eq!(f[0].line, 3, "reported at the call site");
        assert!(f[0].message.contains("helper"));
    }

    #[test]
    fn legal_direction_produces_no_findings() {
        let a = "fn caller(s: &S) {\n    let q = s.queue.lock_or_recover();\n    helper(s);\n}\n";
        let b = "fn helper(s: &S) {\n    let c = s.counters.lock_or_recover();\n    c.bump();\n}\n";
        assert!(run(lock_graph, &[("a.rs", a), ("b.rs", b)]).is_empty());
    }

    #[test]
    fn transitive_propagation_through_two_calls() {
        let a = "fn top(s: &S) {\n    let h = s.health.lock_or_recover();\n    mid(s);\n}\nfn mid(s: &S) {\n    bottom(s);\n}\nfn bottom(s: &S) {\n    let c = s.stats.lock_or_recover();\n    c.bump();\n}\n";
        let f = run(lock_graph, &[("a.rs", a)]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn same_level_cycle_detected() {
        // stats → counters in one fn, counters → stats in another: both
        // legal by level (2 == 2), deadlock-prone as a cycle.
        let a = "fn one(s: &S) {\n    let g = s.stats.lock_or_recover();\n    let c = s.counters.lock_or_recover();\n}\nfn two(s: &S) {\n    let c = s.counters.lock_or_recover();\n    let g = s.stats.lock_or_recover();\n}\n";
        let f = run(lock_graph, &[("a.rs", a)]);
        assert_eq!(f.len(), 2, "one finding per cycle edge: {f:?}");
        assert!(f.iter().all(|x| x.message.contains("cycle")));
    }

    #[test]
    fn derived_order_matches_declared_on_legal_graph() {
        let a = "fn f(s: &S) {\n    let q = s.queue.lock_or_recover();\n    let c = s.counters.lock_or_recover();\n    let h = s.health.lock_or_recover();\n}\n";
        let owned = views(&[("a.rs", a)]);
        let fv: Vec<FileView> = owned
            .iter()
            .map(|(p, s, t)| FileView { path: p, s, t })
            .collect();
        let g = build_lock_graph(&fv);
        let order = topo_order(&g).expect("acyclic");
        let pos = |c: &str| order.iter().position(|&x| x == c).unwrap();
        assert!(pos("router-lanes") < pos("metrics"));
        assert!(pos("metrics") < pos("health"));
        for e in &g.edges {
            assert!(rules::class_level(e.from) <= rules::class_level(e.to));
        }
    }

    #[test]
    fn atomic_relaxed_publish_gating_load_both_flagged() {
        let src = "fn stop(s: &S) {\n    s.stopping.store(true, Ordering::Relaxed);\n}\nfn poll(s: &S) {\n    if s.stopping.load(Ordering::Relaxed) {\n        return;\n    }\n}\n";
        let f = run(atomic_ordering, &[("a.rs", src)]);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().any(|x| x.line == 2), "publish side flagged");
        assert!(f.iter().any(|x| x.line == 5), "load side flagged");
    }

    #[test]
    fn atomic_release_acquire_pair_is_clean() {
        let src = "fn stop(s: &S) {\n    s.stopping.store(true, Ordering::Release);\n}\nfn poll(s: &S) {\n    if s.stopping.load(Ordering::Acquire) {\n        return;\n    }\n}\n";
        assert!(run(atomic_ordering, &[("a.rs", src)]).is_empty());
    }

    #[test]
    fn atomic_same_function_pair_is_not_cross_thread() {
        let src = "fn local(s: &S) {\n    s.flag.store(true, Ordering::Relaxed);\n    if s.flag.load(Ordering::Relaxed) {\n        return;\n    }\n}\n";
        assert!(run(atomic_ordering, &[("a.rs", src)]).is_empty());
    }

    #[test]
    fn atomic_counter_allowlist_spares_publish_side() {
        // `tries` is in RELAXED_COUNTERS: its Relaxed publishes are fine
        // even if some test gates on the count; the gating Relaxed load
        // itself is still reported (pragma territory).
        let src = "fn bump(s: &S) {\n    s.tries.fetch_add(1, Ordering::Relaxed);\n}\nfn check(s: &S) {\n    if s.tries.load(Ordering::Relaxed) > 3 {\n        return;\n    }\n}\n";
        let f = run(atomic_ordering, &[("a.rs", src)]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 5, "only the load side remains");
    }

    #[test]
    fn atomic_non_gating_load_is_clean() {
        let src = "fn bump(s: &S) {\n    s.total.fetch_add(1, Ordering::Relaxed);\n}\nfn report(s: &S) -> u64 {\n    s.total.load(Ordering::Relaxed)\n}\n";
        assert!(run(atomic_ordering, &[("a.rs", src)]).is_empty());
    }

    #[test]
    fn gating_shapes() {
        // negation and comparison adjacency, outside an if/while span
        let src = "fn pub_(s: &S) {\n    s.n.store(1, Ordering::Relaxed);\n}\nfn g(s: &S) -> bool {\n    let more = s.n.load(Ordering::Relaxed) >= LIMIT;\n    more\n}\n";
        let f = run(atomic_ordering, &[("a.rs", src)]);
        assert_eq!(f.len(), 2, "comparison makes the load gating: {f:?}");
    }
}
