//! Source sanitizer: a comment/string/char-literal-aware pass over Rust
//! source that (a) blanks everything that is not code, so the rule engine
//! can match patterns with naive text search and never trip on a comment
//! or a string literal, and (b) extracts `sonic-lint:` suppression
//! pragmas from the comments it blanks.
//!
//! This is deliberately *not* a Rust parser.  It tracks exactly the
//! lexical states that can hide code-looking text — line comments,
//! (nested) block comments, string literals with escapes, raw strings
//! with `#` fences, byte strings, and char literals (disambiguated from
//! lifetimes) — and replaces their contents with spaces, preserving line
//! structure so findings keep real line numbers.

/// A parsed suppression pragma: the comment form
/// `allow(rule-a, rule-b): justification` behind the sonic-lint marker.
#[derive(Debug, Clone)]
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: usize,
    /// Rule names listed inside `allow(...)`.
    pub rules: Vec<String>,
    /// Whether a non-empty justification followed the rule list.
    pub justified: bool,
    /// Raw comment text (diagnostics for malformed pragmas).
    pub text: String,
}

/// Sanitized view of one source file.
pub struct Sanitized {
    /// The source with comments, strings, and char literals blanked to
    /// spaces.  Same length and line structure as the input.
    pub text: String,
    /// Byte offset of the start of each line (for offset→line lookup).
    line_starts: Vec<usize>,
    /// Every `sonic-lint:` pragma found in the comments.
    pub pragmas: Vec<Pragma>,
}

impl Sanitized {
    /// 1-based line number containing byte offset `off`.
    pub fn line_of(&self, off: usize) -> usize {
        match self.line_starts.binary_search(&off) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// The sanitized text of a 1-based line (without trailing newline).
    pub fn line(&self, line: usize) -> &str {
        let start = self.line_starts[line - 1];
        let end = self
            .line_starts
            .get(line)
            .map(|e| e - 1)
            .unwrap_or(self.text.len());
        &self.text[start..end.max(start)]
    }

    /// Number of lines.
    pub fn line_count(&self) -> usize {
        self.line_starts.len()
    }
}

#[derive(PartialEq)]
enum State {
    Code,
    LineComment,
    /// Block comments nest in Rust; the payload is the nesting depth.
    BlockComment(u32),
    Str,
    RawStr(u32),
    Char,
}

/// Sanitize `src`, blanking non-code bytes and collecting pragmas.
pub fn sanitize(src: &str) -> Sanitized {
    let bytes = src.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut line_starts = vec![0usize];
    let mut pragmas = Vec::new();
    let mut state = State::Code;
    // Accumulates the current comment's text for pragma parsing.
    let mut comment = String::new();
    let mut comment_line = 1usize;
    let mut line = 1usize;
    let mut i = 0usize;

    while i < bytes.len() {
        let c = bytes[i];
        if c == b'\n' {
            if state == State::LineComment {
                flush_pragma(&comment, comment_line, &mut pragmas);
                comment.clear();
                state = State::Code;
            }
            out.push(b'\n');
            line += 1;
            line_starts.push(i + 1);
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == b'/' && bytes.get(i + 1) == Some(&b'/') {
                    state = State::LineComment;
                    comment_line = line;
                    out.push(b' ');
                    i += 1;
                    out.push(b' ');
                    i += 1;
                } else if c == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    state = State::BlockComment(1);
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'"' {
                    state = State::Str;
                    out.push(b' ');
                    i += 1;
                } else if is_raw_string_start(bytes, i) {
                    // r"..."  r#"..."#  br#"..."#  — count the fence.
                    let mut j = i;
                    while bytes[j] != b'#' && bytes[j] != b'"' {
                        j += 1; // skip the r / br prefix
                    }
                    let mut hashes = 0u32;
                    while bytes.get(j) == Some(&b'#') {
                        hashes += 1;
                        j += 1;
                    }
                    // bytes[j] is the opening quote
                    for _ in i..=j {
                        out.push(b' ');
                    }
                    i = j + 1;
                    state = State::RawStr(hashes);
                } else if c == b'\'' && is_char_literal(bytes, i) {
                    state = State::Char;
                    out.push(b' ');
                    i += 1;
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c as char);
                out.push(b' ');
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == b'*' && bytes.get(i + 1) == Some(&b'/') {
                    out.extend_from_slice(b"  ");
                    i += 2;
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                } else if c == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    out.extend_from_slice(b"  ");
                    i += 2;
                    state = State::BlockComment(depth + 1);
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
            State::Str => {
                if c == b'\\' && i + 1 < bytes.len() && bytes[i + 1] != b'\n' {
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'"' {
                    out.push(b' ');
                    i += 1;
                    state = State::Code;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == b'"' && raw_fence_closes(bytes, i, hashes) {
                    for _ in 0..=hashes {
                        out.push(b' ');
                    }
                    i += 1 + hashes as usize;
                    state = State::Code;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
            State::Char => {
                if c == b'\\' && i + 1 < bytes.len() && bytes[i + 1] != b'\n' {
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'\'' {
                    out.push(b' ');
                    i += 1;
                    state = State::Code;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
        }
    }
    if state == State::LineComment {
        flush_pragma(&comment, comment_line, &mut pragmas);
    }

    Sanitized {
        // Only ASCII bytes were substituted, so the output is valid UTF-8.
        text: String::from_utf8(out).expect("sanitizer preserves utf-8"),
        line_starts,
        pragmas,
    }
}

/// Is `bytes[i..]` the start of a raw (byte) string literal?  Requires
/// the previous char to not be identifier-ish, so `attr` or `for` never
/// match.
fn is_raw_string_start(bytes: &[u8], i: usize) -> bool {
    if i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_') {
        return false;
    }
    let mut j = i;
    if bytes.get(j) == Some(&b'b') {
        j += 1;
    }
    if bytes.get(j) != Some(&b'r') {
        return false;
    }
    j += 1;
    while bytes.get(j) == Some(&b'#') {
        j += 1;
    }
    bytes.get(j) == Some(&b'"')
}

/// Does the `"` at `bytes[i]` close a raw string with `hashes` fence
/// characters?
fn raw_fence_closes(bytes: &[u8], i: usize, hashes: u32) -> bool {
    for k in 0..hashes as usize {
        if bytes.get(i + 1 + k) != Some(&b'#') {
            return false;
        }
    }
    true
}

/// Disambiguate a char literal from a lifetime: `'x'` and `'\n'` are
/// literals; `'a` in `&'a str` or `'static` is a lifetime.
fn is_char_literal(bytes: &[u8], i: usize) -> bool {
    match bytes.get(i + 1) {
        Some(b'\\') => true,
        Some(_) => bytes.get(i + 2) == Some(&b'\''),
        None => false,
    }
}

/// Parse a suppression pragma — the sonic-lint marker followed by
/// `allow(rule, ...): justification` — out of a line comment's text.
fn flush_pragma(comment: &str, line: usize, pragmas: &mut Vec<Pragma>) {
    let Some(pos) = comment.find("sonic-lint:") else {
        return;
    };
    let rest = comment[pos + "sonic-lint:".len()..].trim_start();
    if !rest.starts_with("allow") {
        // Prose that merely mentions the marker (docs, READMEs quoted in
        // comments) is not a suppression attempt.
        return;
    }
    let mut rules = Vec::new();
    let mut justified = false;
    if let Some(body) = rest.strip_prefix("allow(") {
        if let Some(close) = body.find(')') {
            for r in body[..close].split(',') {
                let r = r.trim();
                if !r.is_empty() {
                    rules.push(r.to_string());
                }
            }
            justified = body[close + 1..]
                .trim_start()
                .strip_prefix(':')
                .map(|j| !j.trim().is_empty())
                .unwrap_or(false);
        }
    }
    pragmas.push(Pragma {
        line,
        rules,
        justified,
        text: comment.trim().to_string(),
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanks_comments_and_strings() {
        let s = sanitize("let a = 1; // m.lock().unwrap()\nlet b = \"x.lock().unwrap()\";\n");
        assert!(!s.text.contains("unwrap"));
        assert!(s.text.contains("let a = 1;"));
        assert!(s.text.contains("let b ="));
        assert_eq!(s.line_count(), 3); // trailing newline opens an empty line
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let s = sanitize("/* outer /* inner */ still */ code()\nlet r = r#\"lock().unwrap()\"#;\n");
        assert!(s.text.contains("code()"));
        assert!(!s.text.contains("unwrap"));
        assert!(!s.text.contains("still"));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let s = sanitize("fn f<'a>(x: &'a str) -> char { 'x' }\nlet q = '\\'';\n");
        assert!(s.text.contains("<'a>"), "lifetime mangled: {}", s.text);
        assert!(!s.text.contains("'x'"));
    }

    #[test]
    fn parses_pragma_with_justification() {
        let s = sanitize("// sonic-lint: allow(no-lock-unwrap, lock-order): recovery wrapper\nx();\n");
        assert_eq!(s.pragmas.len(), 1);
        let p = &s.pragmas[0];
        assert_eq!(p.line, 1);
        assert_eq!(p.rules, vec!["no-lock-unwrap", "lock-order"]);
        assert!(p.justified);
    }

    #[test]
    fn pragma_without_justification_is_not_justified() {
        let s = sanitize("let g = m.lock(); // sonic-lint: allow(no-lock-unwrap)\n");
        assert_eq!(s.pragmas.len(), 1);
        assert!(!s.pragmas[0].justified);
    }

    #[test]
    fn line_of_maps_offsets() {
        let s = sanitize("a\nbb\nccc\n");
        assert_eq!(s.line_of(0), 1);
        assert_eq!(s.line_of(2), 2);
        assert_eq!(s.line_of(5), 3);
        assert_eq!(s.line("2".parse::<usize>().unwrap()), "bb");
    }
}
