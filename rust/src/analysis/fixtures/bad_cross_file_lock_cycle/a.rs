//! Cross-file lock-cycle fixture, file 1 of 2.  Never compiled —
//! scanned by the lint self-tests *together with* `b.rs`.
//!
//! This file holds a metrics lock and calls into `b.rs`, which
//! acquires a router-lanes lock — a hierarchy inversion (level 2 held
//! while acquiring level 1) that no single function exhibits: PR 9's
//! intra-function `lock-order` rule provably finds nothing here (the
//! self-test asserts exactly that).  Only the whole-crate `lock-graph`
//! pass, propagating acquires-while-holding edges through the call,
//! can see it.

fn flush_report(s: &Subsystems) {
    let c = s.counters.lock_or_recover();
    enqueue_low_priority(s); // lint-expect: lock-graph
    drop(c);
}
