//! Cross-file lock-cycle fixture, file 2 of 2 — see `a.rs`.
//!
//! `enqueue_low_priority` acquires the router-lanes lock that `a.rs`
//! reaches while holding metrics (the inversion).  `note_depth` below
//! nests the same pair in the *declared* direction — legal on its own,
//! but combined with `a.rs` the two orders form a cycle: two threads
//! running `flush_report` and `note_depth` can deadlock.  The edge here
//! is therefore flagged as a cycle participant.

struct Subsystems {
    queue: Mutex<Vec<u64>>,
    counters: Mutex<u64>,
}

fn enqueue_low_priority(s: &Subsystems) {
    let q = s.queue.lock_or_recover();
    q.push(0);
}

fn note_depth(s: &Subsystems) {
    let q = s.queue.lock_or_recover();
    let c = s.counters.lock_or_recover(); // lint-expect: lock-graph
    *c += q.len() as u64;
    drop(c);
    drop(q);
}
