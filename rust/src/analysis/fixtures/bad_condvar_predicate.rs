//! Known-bad fixture for `condvar-predicate`.  Never compiled — scanned
//! by the lint self-tests.  Condvars may wake spuriously: a wait that
//! is not wrapped in a `while`/`loop` re-checking its predicate treats
//! a phantom wakeup as a real completion.
use std::sync::{Condvar, Mutex};
use std::time::Duration;

fn straight_line_wait(m: &Mutex<bool>, cv: &Condvar) {
    let g = m.lock_or_recover();
    let _g = cv.wait_or_recover(g); // lint-expect: condvar-predicate
}

fn if_gated_wait(m: &Mutex<bool>, cv: &Condvar) {
    let g = m.lock_or_recover();
    // An `if` checks once; a spurious wakeup after the check slips by.
    if !*g {
        let (_g, _timed_out) = cv.wait_timeout_or_recover(g, Duration::from_millis(5)); // lint-expect: condvar-predicate
    }
}

fn for_is_not_a_predicate_loop(m: &Mutex<bool>, cv: &Condvar) {
    let mut g = m.lock_or_recover();
    // Bounded retries re-wait but never re-check a predicate per se;
    // `for` runs once per item, so the rule treats it as straight-line.
    for _ in 0..3 {
        g = cv.wait_or_recover(g); // lint-expect: condvar-predicate
    }
    let _ = g;
}

fn good_while_predicate(m: &Mutex<bool>, cv: &Condvar) {
    let mut g = m.lock_or_recover();
    while !*g {
        g = cv.wait_or_recover(g);
    }
}

fn good_loop_with_break(m: &Mutex<bool>, cv: &Condvar) {
    let mut g = m.lock_or_recover();
    loop {
        if *g {
            break;
        }
        let (ng, _timed_out) = cv.wait_timeout_or_recover(g, Duration::from_millis(5));
        g = ng;
    }
}
