//! Known-bad fixture for `no-blocking-on-shared-pool`.  Never compiled —
//! scanned by the lint self-tests.  Blocking on other tasks from inside
//! a closure running *on* the shared kernel pool can park every worker
//! with nobody left to wake them.
use crate::util::pool::shared;

fn bad(ticket: Ticket, cv: &std::sync::Condvar, m: &std::sync::Mutex<bool>) {
    shared().submit(Box::new(move || {
        let _ = ticket.wait(); // lint-expect: no-blocking-on-shared-pool
    }));
    shared().scoped(|s| {
        let g = m.lock_or_recover();
        let _g = cv.wait(g); // lint-expect: no-blocking-on-shared-pool
    });
    shared().submit(Box::new(move || {
        let mut buf = [0u8; 4];
        stream.read_exact(&mut buf); // lint-expect: no-blocking-on-shared-pool
    }));
}

fn good(ticket: Ticket, pool: &crate::util::pool::Pool) {
    // Blocking is fine on a *dedicated* pool or on the caller's thread.
    let _ = ticket.wait();
    pool.scoped(|s| {
        s.submit(|| compute());
    });
}
