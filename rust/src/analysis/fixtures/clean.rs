//! Clean fixture: every rule enabled, zero findings expected.  Exercises
//! the lexical corners most likely to false-positive — bad patterns in
//! comments, strings, raw strings, and char literals, plus the blessed
//! spellings of each invariant (including the PR 10 concurrency rules:
//! predicate-looped condvar waits, parking poll loops, Release/Acquire
//! publish pairs, and non-gating Relaxed counters).
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

fn lifetimes<'a>(x: &'a str, _c: char) -> &'a str {
    let _apostrophe = '\'';
    let _letter = 'x';
    x
}

fn strings_and_comments() -> String {
    // looks bad but is a comment: m.lock().unwrap()
    let s = "m.lock().unwrap()";
    let r = r#"a.partial_cmp(b).unwrap()"#;
    /* block comment: d.as_nanos() as u32
       /* nested: cv.wait(g).unwrap() */ still inside */
    format!("{s}{r}")
}

fn durations(d: Duration, n: u64) -> u64 {
    let per = (d.as_nanos() / n.max(1) as u128) as u64;
    let sat = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
    let wide = d.as_millis() as f64;
    per + sat + wide as u64
}

fn floats(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}

fn locks(m: &Mutex<u64>, cv: &Condvar) -> u64 {
    // The predicate loop around the wait is what condvar-predicate
    // demands: a spurious wakeup just re-checks and waits again.
    let mut g = m.lock_or_recover();
    while *g == 0 {
        let (ng, timed_out) = cv.wait_timeout_or_recover(g, Duration::from_millis(5));
        g = ng;
        if timed_out.timed_out() {
            break;
        }
    }
    *g
}

fn condvar_in_loop(m: &Mutex<bool>, cv: &Condvar) {
    let mut g = m.lock_or_recover();
    loop {
        if *g {
            break;
        }
        g = cv.wait_or_recover(g);
    }
}

fn tickets(t: Ticket) {
    // Ticket::wait() takes no guard — not a condvar wait.
    let _ = t.wait().unwrap();
    let _ = t.wait_timeout(Duration::from_secs(1)).unwrap();
}

fn io_reads(stream: &mut TcpStream, buf: &mut [u8]) {
    // io::Read::read takes a buffer — not an RwLock read().
    let _ = stream.read(buf).unwrap();
}

struct Shared {
    closing: AtomicBool,
    served: AtomicU64,
}

fn publish_done_right(sh: &Shared) {
    // Cross-thread flag published with Release …
    sh.closing.store(true, Ordering::Release);
}

fn observe_done_right(sh: &Shared) -> bool {
    // … and gated with Acquire: atomic-ordering stays quiet.
    if sh.closing.load(Ordering::Acquire) {
        return true;
    }
    false
}

fn counter_bump(sh: &Shared) {
    // Relaxed is fine for a stat counter nothing gates on.
    sh.served.fetch_add(1, Ordering::Relaxed);
}

fn counter_report(sh: &Shared) -> u64 {
    // Non-gating Relaxed load of the same counter: also fine.
    sh.served.load(Ordering::Relaxed)
}

fn same_function_handoff(once: &AtomicBool) -> bool {
    // Publish and gate in the *same* function is not a cross-thread
    // protocol; atomic-ordering only pairs across functions.  (The
    // receiver name is deliberately distinct from the `flag` fields the
    // polling fns below gate on — fields are keyed crate-wide by name.)
    once.store(true, Ordering::Relaxed);
    if once.load(Ordering::Relaxed) {
        return true;
    }
    false
}

fn backoff_poll(flag: &AtomicBool) {
    // Polling an atomic is fine when the loop parks between probes.
    while !flag.load(Ordering::Acquire) {
        std::thread::sleep(Duration::from_millis(1));
    }
}

fn yielding_drain(pending: &AtomicU64) {
    // yield_now is a deliberate scheduling decision, not a busy-wait.
    while pending.load(Ordering::Acquire) > 0 {
        std::thread::yield_now();
    }
}

fn working_poll(flag: &AtomicBool, q: &WorkQueue) {
    // The loop body does real work; the atomic check is incidental.
    while !flag.load(Ordering::Acquire) {
        q.drain_one();
    }
}
