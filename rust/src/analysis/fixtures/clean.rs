//! Clean fixture: every rule enabled, zero findings expected.  Exercises
//! the lexical corners most likely to false-positive — bad patterns in
//! comments, strings, raw strings, and char literals, plus the blessed
//! spellings of each invariant.
use std::sync::{Condvar, Mutex};
use std::time::Duration;

fn lifetimes<'a>(x: &'a str, _c: char) -> &'a str {
    let _apostrophe = '\'';
    let _letter = 'x';
    x
}

fn strings_and_comments() -> String {
    // looks bad but is a comment: m.lock().unwrap()
    let s = "m.lock().unwrap()";
    let r = r#"a.partial_cmp(b).unwrap()"#;
    /* block comment: d.as_nanos() as u32
       /* nested: cv.wait(g).unwrap() */ still inside */
    format!("{s}{r}")
}

fn durations(d: Duration, n: u64) -> u64 {
    let per = (d.as_nanos() / n.max(1) as u128) as u64;
    let sat = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
    let wide = d.as_millis() as f64;
    per + sat + wide as u64
}

fn floats(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}

fn locks(m: &Mutex<u64>, cv: &Condvar) -> u64 {
    let g = m.lock_or_recover();
    let (g, _timed_out) = cv.wait_timeout_or_recover(g, Duration::from_millis(5));
    *g
}

fn tickets(t: Ticket) {
    // Ticket::wait() takes no guard — not a condvar wait.
    let _ = t.wait().unwrap();
    let _ = t.wait_timeout(Duration::from_secs(1)).unwrap();
}

fn io_reads(stream: &mut TcpStream, buf: &mut [u8]) {
    // io::Read::read takes a buffer — not an RwLock read().
    let _ = stream.read(buf).unwrap();
}
