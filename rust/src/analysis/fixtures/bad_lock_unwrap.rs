//! Known-bad fixture for `no-lock-unwrap`.  Never compiled — scanned by
//! the lint self-tests; each `lint-expect` marker names the rule that
//! must fire on exactly that line.
use std::sync::{Condvar, Mutex, RwLock};

fn bad(m: &Mutex<u32>, l: &RwLock<u32>, cv: &Condvar) {
    let g = m.lock().unwrap(); // lint-expect: no-lock-unwrap
    let r = l.read().unwrap(); // lint-expect: no-lock-unwrap
    let w = l.write().expect("poisoned"); // lint-expect: no-lock-unwrap
    let g2 = cv.wait(g).unwrap(); // lint-expect: no-lock-unwrap
    let _ = (g2, r, w);
}

fn bad_multiline(m: &Mutex<Vec<u32>>) {
    m.lock() // lint-expect: no-lock-unwrap
        .unwrap()
        .push(1);
}

fn bad_timeout(cv: &Condvar, m: &Mutex<bool>) {
    let g = m.lock_or_recover();
    let _ = cv.wait_timeout(g, DUR).unwrap(); // lint-expect: no-lock-unwrap
}

fn suppressed(m: &Mutex<u32>) {
    // sonic-lint: allow(no-lock-unwrap): fixture demonstrating a justified pragma
    let _g = m.lock().unwrap();
}

fn not_code(m: &Mutex<u32>) {
    let _s = "m.lock().unwrap()";
    // only a comment: m.lock().unwrap()
    let _g = m.lock_or_recover();
}
