//! Known-bad fixture for `no-duration-narrowing`.  Never compiled —
//! scanned by the lint self-tests.  `as_nanos()` overflows u32 after
//! 4.3 s and `as_millis()` after 49.7 days; the truncation is silent.
use std::time::{Duration, Instant};

fn bad(d: Duration, t0: Instant) -> u64 {
    let a = d.as_nanos() as u64; // lint-expect: no-duration-narrowing
    let b = d.as_millis() as u32; // lint-expect: no-duration-narrowing
    let c = t0.elapsed().as_micros() as u64; // lint-expect: no-duration-narrowing
    let s = d.as_secs() as u32; // lint-expect: no-duration-narrowing
    a + b as u64 + c + s as u64
}

fn good(d: Duration, n: u64) -> u64 {
    // Divide in u128 first, clamp explicitly, or saturate via try_from.
    let per = (d.as_nanos() / n.max(1) as u128) as u64;
    let clamped = d.as_nanos().min(u64::MAX as u128) as u64;
    let sat = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
    let secs = d.as_secs();
    per + clamped + sat + secs
}
