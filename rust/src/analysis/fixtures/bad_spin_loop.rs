//! Known-bad fixture for `no-spin-loop`.  Never compiled — scanned by
//! the lint self-tests.  A loop that only polls atomics burns a core
//! and, on a shared pool, can starve the very thread that would flip
//! the flag.
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

fn busy_wait_flag(flag: &AtomicBool) {
    while !flag.load(Ordering::Acquire) {} // lint-expect: no-spin-loop
}

fn busy_drain_gauge(pending: &AtomicU64) {
    loop { // lint-expect: no-spin-loop
        if pending.load(Ordering::Acquire) == 0 {
            break;
        }
    }
}

fn good_backoff_sleep(flag: &AtomicBool) {
    while !flag.load(Ordering::Acquire) {
        std::thread::sleep(Duration::from_millis(1));
    }
}

fn good_yielding_drain(pending: &AtomicU64) {
    while pending.load(Ordering::Acquire) > 0 {
        std::thread::yield_now();
    }
}

fn good_polling_with_work(flag: &AtomicBool, q: &WorkQueue) {
    // The loop makes progress itself — polling is incidental.
    while !flag.load(Ordering::Acquire) {
        q.drain_one();
    }
}
