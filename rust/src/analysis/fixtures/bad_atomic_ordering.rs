//! Known-bad fixture for `atomic-ordering`.  Never compiled — scanned
//! by the lint self-tests.  A `Relaxed` half of a cross-function
//! publish → gating-load pair synchronizes nothing: the loading thread
//! may never observe the store in any useful happens-before order.
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

struct Flags {
    stopping: AtomicBool,
    drain_requested: AtomicBool,
    total_served: AtomicU64,
}

fn shutdown(f: &Flags) {
    // The worker gates on this flag from another thread: Relaxed cannot
    // publish the preceding writes to it.
    f.stopping.store(true, Ordering::Relaxed); // lint-expect: atomic-ordering
}

fn worker_poll(f: &Flags) -> bool {
    // The load side is Acquire — correct — so only the store above is
    // flagged.
    if f.stopping.load(Ordering::Acquire) {
        return true;
    }
    false
}

fn request_drain(f: &Flags) {
    // Publish side done right …
    f.drain_requested.store(true, Ordering::Release);
}

fn accept_loop(f: &Flags) {
    // … but the gating load is Relaxed: the accept loop may spin on a
    // stale false forever as far as the memory model cares.
    while !f.drain_requested.load(Ordering::Relaxed) { // lint-expect: atomic-ordering
        serve_one(f);
    }
}

fn bump(f: &Flags) {
    // Monotonic stat counter: Relaxed is fine — nothing gates on it.
    f.total_served.fetch_add(1, Ordering::Relaxed);
}

fn report(f: &Flags) -> u64 {
    f.total_served.load(Ordering::Relaxed)
}
