//! Known-bad fixture for `lock-order`.  Never compiled — scanned by the
//! lint self-tests.  The declared hierarchy is
//! engine → router-lanes → metrics → health: nested acquisition may only
//! move rightward, or two threads taking the pair in opposite orders
//! deadlock.
use std::sync::Mutex;

struct Subsystems {
    queue: Mutex<Vec<u64>>,
    counters: Mutex<u64>,
    health: Mutex<u8>,
}

fn bad(s: &Subsystems) {
    let h = s.health.lock_or_recover();
    let c = s.counters.lock_or_recover(); // lint-expect: lock-order
    drop(c);
    drop(h);
}

fn bad_transient(s: &Subsystems) {
    let c = s.counters.lock_or_recover();
    s.queue.lock_or_recover().push(1); // lint-expect: lock-order
    drop(c);
}

fn good(s: &Subsystems) {
    // Sequential, never nested.  (Nesting queue → counters here would be
    // legal for `lock-order`, but `bad_transient` above inverts the same
    // pair, and the whole-crate `lock-graph` rule would then see a cycle
    // — that two-function shape lives in bad_cross_file_lock_cycle/.)
    let q = s.queue.lock_or_recover();
    drop(q);
    let c = s.counters.lock_or_recover();
    drop(c);
    let h = s.health.lock_or_recover();
    drop(h);
}

fn good_scoped(s: &Subsystems) {
    {
        let h = s.health.lock_or_recover();
        let _ = *h;
    }
    // The health guard died with its scope; metrics is safe now.
    let c = s.counters.lock_or_recover();
    drop(c);
}

fn good_transient_chain(s: &Subsystems) {
    // A chained access releases at statement end; the binding below is
    // the value, not the guard.
    let held = s.health.lock_or_recover().wrapping_add(1);
    let c = s.counters.lock_or_recover();
    drop(c);
    let _ = held;
}
