//! Known-bad fixture for `no-partial-cmp-unwrap`.  Never compiled —
//! scanned by the lint self-tests.

fn bad(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); // lint-expect: no-partial-cmp-unwrap
    let _m = xs
        .iter()
        .cloned()
        .max_by(|a, b| a.partial_cmp(b).expect("nan")); // lint-expect: no-partial-cmp-unwrap
}

fn good(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.total_cmp(b));
    // partial_cmp without the unwrap is legitimate:
    let _ = xs[0].partial_cmp(&xs[1]).unwrap_or(std::cmp::Ordering::Equal);
}
