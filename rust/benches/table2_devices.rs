//! Bench: Table 2 — device parameters used for accelerator analysis.
//!
//! Prints the table from the code constants (single source of truth) and
//! micro-benchmarks the device cost-model evaluations that sit on the
//! simulator's inner loop.

use sonic::arch::{SonicConfig, VduKind};
use sonic::devices::{DeviceParams, Mr, MrBank};
use sonic::util::bench::{black_box, report, Bencher, Table};

fn main() {
    println!("=== Table 2: parameters considered for analysis ===\n");
    let p = DeviceParams::default();
    let mut t = Table::new(&["device", "latency", "power"]);
    for (name, lat, pow) in p.table2_rows() {
        t.row(&[name, lat, pow]);
    }
    t.print();

    // Consistency assertions pinning the Table-2 values.
    assert_eq!(p.eo_latency_s, 20e-9);
    assert_eq!(p.to_latency_s, 4e-6);
    assert_eq!(p.vcsel_latency_s, 0.07e-9);
    assert_eq!(p.pd_latency_s, 5.8e-12);
    assert_eq!(p.dac16_latency_s, 0.33e-9);
    assert_eq!(p.dac6_latency_s, 0.25e-9);
    assert_eq!(p.adc_latency_s, 14e-9);

    println!("\n--- derived quantities ---");
    let cfg = SonicConfig::paper_best();
    let conv = cfg.conv_vdu();
    let fc = cfg.fc_vdu();
    println!(
        "VDU initiation interval: conv {} ns, fc {} ns (EO-retune bound)",
        conv.initiation_interval_s() * 1e9,
        fc.initiation_interval_s() * 1e9
    );
    println!(
        "VDU fill latency: conv {:.2} ns, fc {:.2} ns",
        conv.fill_latency_s() * 1e9,
        fc.fill_latency_s() * 1e9
    );
    assert_eq!(conv.kind, VduKind::Conv);
    assert_eq!(fc.kind, VduKind::Fc);

    println!("\n--- timing: device model evaluation (simulator inner loop) ---");
    let mr = Mr::new(p.clone());
    let st = Bencher::default().run(|| {
        for i in 0..100 {
            black_box(mr.shift_for_transmission(i as f64 / 100.0));
        }
    });
    report("Mr::shift_for_transmission x100", &st);

    let bank = MrBank::new(p.clone(), 50);
    let st = Bencher::default().run(|| {
        black_box(bank.avg_hold_power_w(0.5, 25));
    });
    report("MrBank::avg_hold_power_w", &st);

    let st = Bencher::default().run(|| {
        black_box(fc.pass_cost(25, 0.5));
    });
    report("Vdu::pass_cost (fc, 50 lanes)", &st);
}
