//! Bench: Fig. 6 — sparsity x clustering x layers-pruned design-space
//! exploration (CIFAR10).
//!
//! The sweep itself runs in Python at build time (real training +
//! clustering on the synthetic CIFAR10 stand-in; `make artifacts` emits
//! `artifacts/fig6_dse.json`).  This bench renders the figure's data and
//! asserts its qualitative shape: very few clusters hurt accuracy, and the
//! best point uses >= 16 clusters — consistent with the paper selecting 16
//! clusters for CIFAR10.

use sonic::util::bench::Table;
use sonic::util::json::Json;

fn main() {
    println!("=== Fig. 6: sparsity & clustering exploration (CIFAR10) ===\n");
    let art = sonic::artifacts_dir();
    let Ok(text) = std::fs::read_to_string(art.join("fig6_dse.json")) else {
        println!("artifacts/fig6_dse.json missing — run `make artifacts` first.");
        println!("(bench exits OK so `cargo bench` works pre-artifacts)");
        return;
    };
    let j = Json::parse(&text).expect("fig6_dse.json parses");
    let rows = j.req("rows").unwrap().as_arr().unwrap();
    let best = j.req("best").unwrap();

    let mut t = Table::new(&["layers", "sparsity", "clusters", "accuracy", "params left"]);
    for r in rows {
        t.row(&[
            r.req("layers").unwrap().as_i64().unwrap().to_string(),
            format!("{:.1}", r.req("sparsity").unwrap().as_f64().unwrap()),
            r.req("clusters").unwrap().as_i64().unwrap().to_string(),
            format!("{:.2}%", r.req("accuracy").unwrap().as_f64().unwrap()),
            r.req("surviving_params").unwrap().as_usize().unwrap().to_string(),
        ]);
    }
    t.print();
    println!(
        "\nbest point: layers={} sparsity={} clusters={} accuracy={:.2}%",
        best.req("layers").unwrap().as_i64().unwrap(),
        best.req("sparsity").unwrap().as_f64().unwrap(),
        best.req("clusters").unwrap().as_i64().unwrap(),
        best.req("accuracy").unwrap().as_f64().unwrap()
    );

    // Shape assertions.
    let acc = |cl: i64| -> f64 {
        let xs: Vec<f64> = rows
            .iter()
            .filter(|r| r.req("clusters").unwrap().as_i64() == Some(cl))
            .map(|r| r.req("accuracy").unwrap().as_f64().unwrap())
            .collect();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    let lo = acc(4);
    let hi = acc(16).max(acc(64));
    println!("\nmean accuracy @4 clusters {lo:.2}% vs @>=16 clusters {hi:.2}%");
    assert!(hi >= lo, "few clusters must not beat many clusters on average");
    let best_clusters = best.req("clusters").unwrap().as_i64().unwrap();
    assert!(best_clusters >= 16, "best point uses >= 16 clusters (paper: 16)");
    println!("shape checks passed");
}
