//! Bench: Table 1 — baseline CNN models (layer counts, parameter totals).
//!
//! Verifies the reconstructed architectures against the paper's numbers
//! (from builtin descriptors, and against `artifacts/*.json` when built),
//! and times descriptor loading (a coordinator startup cost).

use sonic::model::{LayerKind, ModelDesc};
use sonic::util::bench::{black_box, report, Bencher, Table};

fn main() {
    println!("=== Table 1: CNN models considered for experiments ===\n");
    let paper: &[(&str, usize, usize, usize, f64)] = &[
        ("mnist", 2, 2, 1_498_730, 93.2),
        ("cifar10", 6, 1, 552_874, 86.05),
        ("stl10", 6, 1, 77_787_738, 74.6),
        ("svhn", 4, 3, 552_362, 94.6),
    ];

    let mut t = Table::new(&[
        "dataset",
        "conv",
        "fc",
        "params (ours)",
        "params (paper)",
        "delta",
        "acc (paper)",
    ]);
    for &(name, conv_want, _fc_want, params_want, acc) in paper {
        let d = ModelDesc::builtin(name).unwrap();
        let convs = d
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv { .. }))
            .count();
        assert_eq!(convs, conv_want, "{name} conv count");
        let total: usize = d.layers.iter().map(|l| l.n_params()).sum();
        let delta = total as i64 - params_want as i64;
        assert!(delta.abs() <= 4, "{name}: param delta {delta}");
        t.row(&[
            name.into(),
            convs.to_string(),
            (d.layers.len() - convs).to_string(),
            total.to_string(),
            params_want.to_string(),
            format!("{delta:+}"),
            format!("{acc}%"),
        ]);
    }
    t.print();

    // measured descriptors, if artifacts exist
    let art = sonic::artifacts_dir();
    if art.join("mnist.json").is_file() {
        println!("\n(artifacts found: measured descriptors load + agree)");
        for &(name, ..) in paper {
            let d = ModelDesc::load_or_builtin(name);
            let b = ModelDesc::builtin(name).unwrap();
            assert_eq!(d.total_params, b.total_params, "{name} artifact total");
        }
    }

    println!("\n--- timing: descriptor construction & load ---");
    let st = Bencher::default().run(|| {
        black_box(ModelDesc::builtin("stl10").unwrap());
    });
    report("ModelDesc::builtin(stl10)", &st);
    let st = Bencher::default().run(|| {
        black_box(ModelDesc::load_or_builtin("cifar10"));
    });
    report("ModelDesc::load_or_builtin(cifar10)", &st);
}
