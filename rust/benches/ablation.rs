//! Bench: ablation of SONIC's three co-design levers (DESIGN.md §4,
//! "ablations (ours)"): VCSEL power gating, weight clustering, and
//! dataflow compression — individually and combined — on every model.

use sonic::model::ModelDesc;
use sonic::sim::ablation::ablate;
use sonic::util::bench::{black_box, report, Bencher, Table};
use sonic::util::si;

fn main() {
    println!("=== Ablation: co-design levers ===\n");
    for name in ["mnist", "cifar10", "stl10", "svhn"] {
        let desc = ModelDesc::load_or_builtin(name);
        let rows = ablate(&desc);
        let mut t = Table::new(&["variant", "FPS", "power", "FPS/W", "EPB", "FPS/W rel", "EPB rel"]);
        for r in &rows {
            t.row(&[
                r.variant.to_string(),
                format!("{:.0}", r.stats.fps),
                format!("{:.2} W", r.stats.avg_power_w),
                format!("{:.1}", r.stats.fps_per_watt),
                si(r.stats.epb_j, "J/b"),
                format!("{:.2}x", r.fps_per_watt_rel),
                format!("{:.2}x", r.epb_rel),
            ]);
        }
        println!("--- {name} ---");
        t.print();
        println!();

        // Full config dominates; each lever contributes.
        for r in &rows[1..] {
            assert!(r.fps_per_watt_rel <= 1.0 + 1e-9, "{name}/{}", r.variant);
        }
        let dense = rows.last().unwrap();
        assert!(
            dense.epb_rel > 2.0,
            "{name}: dense photonic variant must cost >2x EPB (got {:.2})",
            dense.epb_rel
        );
    }

    println!("--- timing ---");
    let desc = ModelDesc::load_or_builtin("cifar10");
    let st = Bencher::default().run(|| {
        black_box(ablate(&desc));
    });
    report("ablate(cifar10) [6 variants]", &st);
}
