//! Bench: Fig. 9 — power efficiency (FPS/W) across the accelerator
//! platforms, plus the paper's headline average ratios:
//! SONIC = 5.81x NullHop, 4.02x RSNN, 3.08x LightBulb, 2.94x CrossLight,
//! 13.8x HolyLight (geometric mean over the four workloads).

use sonic::arch::SonicConfig;
use sonic::baselines::all_platforms;
use sonic::model::ModelDesc;
use sonic::sim::simulate;
use sonic::util::bench::{black_box, report, Bencher, Table};

fn main() {
    println!("=== Fig. 9: FPS/W comparison ===\n");
    let cfg = SonicConfig::paper_best();
    let platforms = all_platforms();
    let models = ["mnist", "cifar10", "stl10", "svhn"];

    let mut headers = vec!["model".to_string(), "SONIC".to_string()];
    headers.extend(platforms.iter().map(|p| p.name().to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);

    for name in models {
        let desc = ModelDesc::load_or_builtin(name);
        let sonic = simulate(&desc, &cfg);
        let mut row = vec![name.to_string(), format!("{:.1}", sonic.fps_per_watt)];
        for p in &platforms {
            row.push(format!("{:.2}", p.evaluate(&desc).fps_per_watt));
        }
        t.row(&row);
    }
    t.print();

    println!("\n--- average ratios (geomean over models; paper value in brackets) ---");
    let targets = [
        ("NullHop", 5.81),
        ("RSNN", 4.02),
        ("LightBulb", 3.08),
        ("CrossLight", 2.94),
        ("HolyLight", 13.8),
    ];
    for (pname, want) in targets {
        let p = platforms.iter().find(|p| p.name() == pname).unwrap();
        let mut prod = 1.0;
        for name in models {
            let desc = ModelDesc::load_or_builtin(name);
            let s = simulate(&desc, &cfg);
            prod *= s.fps_per_watt / p.evaluate(&desc).fps_per_watt;
        }
        let gm: f64 = prod.powf(1.0 / models.len() as f64);
        let ok = (gm / want - 1.0).abs() < 0.25;
        println!("  SONIC vs {pname:<11}: {gm:6.2}x   [paper {want}x]  {}",
                 if ok { "OK" } else { "OUT OF BAND" });
        assert!(ok, "{pname}: ratio {gm} vs paper {want}");
        assert!(gm > 1.0, "{pname}: SONIC must win");
    }

    println!("\n--- timing ---");
    let desc = ModelDesc::load_or_builtin("svhn");
    let st = Bencher::default().run(|| {
        black_box(simulate(&desc, &cfg).fps_per_watt);
    });
    report("simulate(svhn) -> FPS/W", &st);
}
