//! Bench: Fig. 8 — power consumption across the accelerator platforms.
//!
//! Regenerates the figure's bars (one row per model, one column per
//! platform) from the analytic simulator + baseline models, asserts the
//! paper's qualitative shape (SONIC draws more power than the electronic
//! sparse accelerators but far less than GPU/CPU), and times the
//! simulator-side work that produces the figure.

use sonic::arch::SonicConfig;
use sonic::baselines::all_platforms;
use sonic::model::ModelDesc;
use sonic::sim::simulate;
use sonic::util::bench::{black_box, report, Bencher, Table};

fn main() {
    println!("=== Fig. 8: power comparison (W) ===\n");
    let cfg = SonicConfig::paper_best();
    let platforms = all_platforms();
    let models = ["mnist", "cifar10", "stl10", "svhn"];

    let mut headers = vec!["model".to_string(), "SONIC".to_string()];
    headers.extend(platforms.iter().map(|p| p.name().to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);

    for name in models {
        let desc = ModelDesc::load_or_builtin(name);
        let sonic = simulate(&desc, &cfg);
        let mut row = vec![name.to_string(), format!("{:.2}", sonic.avg_power_w)];
        for p in &platforms {
            let r = p.evaluate(&desc);
            row.push(format!("{:.2}", r.power_w));
        }
        t.row(&row);

        // Paper shape: SONIC's power exceeds the electronic SpNN
        // accelerators' but stays far below GPU/CPU.
        let nullhop = platforms[0].evaluate(&desc).power_w;
        let rsnn = platforms[1].evaluate(&desc).power_w;
        let gpu = platforms[5].evaluate(&desc).power_w;
        let cpu = platforms[6].evaluate(&desc).power_w;
        assert!(sonic.avg_power_w > nullhop, "{name}: SONIC vs NullHop power");
        assert!(sonic.avg_power_w > rsnn, "{name}: SONIC vs RSNN power");
        assert!(sonic.avg_power_w < gpu * 0.5, "{name}: SONIC vs GPU power");
        assert!(sonic.avg_power_w < cpu * 0.5, "{name}: SONIC vs CPU power");
    }
    t.print();
    println!("\nshape checks passed: NullHop/RSNN < SONIC << NP100/IXP\n");

    println!("--- timing: figure generation path ---");
    let desc = ModelDesc::load_or_builtin("cifar10");
    let st = Bencher::default().run(|| {
        black_box(simulate(&desc, &cfg));
    });
    report("simulate(cifar10, paper_best)", &st);
    let st = Bencher::default().run(|| {
        for p in &platforms {
            black_box(p.evaluate(&desc));
        }
    });
    report("evaluate 7 baselines (cifar10)", &st);
}
