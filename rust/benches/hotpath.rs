//! Bench: L3 coordinator hot paths — the code that runs per request in a
//! real deployment: FC compression, CONV patch extraction + compressed
//! dot products, VDU scheduling, plan compilation/caching, and the
//! analytic simulator itself.  This is the primary input to the §Perf
//! optimization loop.
//!
//! The headline comparison is **plan-cached vs re-planned serving**: the
//! re-planned path rebuilds the FC dataflow compression for every request
//! (gathering kept weight columns into a fresh matrix — what the serving
//! loop did before the `LayerPlan` IR); the plan-cached path executes the
//! precompiled `FcExec` layout with the batched sparse matvec kernel,
//! streaming the weights once per batch.  A second serving comparison
//! tracks the `serve::Engine` facade's cost over the raw backend call
//! (ticketing + queue hand-off + dynamic batching).  Kernel grids (all
//! four FC kernels — dense/csc/csr/bitmap — across weight density x
//! batch with the cost model's pick checked against the measured oracle,
//! and activation-gated-vs-ungated across act sparsity x batch) land in
//! `BENCH_kernels.json` / `BENCH_actgate.json`; the QoS
//! grid (priority mix x deadline mix under an overloaded engine, per-lane
//! p99 + shed counts) lands in `BENCH_qos.json`; the cluster chaos grid
//! (availability / retry amplification / hung-ticket count with a replica
//! killed or stalled mid-load) lands in `BENCH_cluster.json`; everything
//! else in `BENCH_hotpath.json` for the perf trajectory (CI uploads all).

use std::sync::Arc;
use std::time::Duration;

use sonic::arch::SonicConfig;
use sonic::coordinator::compress::{compress_fc, fc_product};
use sonic::coordinator::convflow::{
    compressed_dot, conv2d_compressed, extract_patch, CompressedKernel,
};
use sonic::coordinator::schedule::{schedule_conv, schedule_fc, schedule_layer};
use sonic::model::ModelDesc;
use sonic::plan::{cached, FcExec, KernelChoice, KernelPolicy, ModelPlan, PlanBackend};
use sonic::serve::cluster::{
    ChaosEvent, ChaosSpec, ClusterConfig, ClusterEngine, FaultKind, HealthPolicy, RetryPolicy,
};
use sonic::serve::{
    BackendChoice, Engine, InferenceBackend, NullBackend, Priority, ServeConfig, SubmitOptions,
};
use sonic::sim::simulate;
use sonic::sparsity::ColMatrix;
use sonic::tensor::BatchTensor;
use sonic::util::bench::{black_box, report, Bencher, Stats};
use sonic::util::json::{arr, num, obj, s};
use sonic::util::rng::Rng;

/// `--iters N` bounds every benchmark to N samples (CI smoke mode:
/// record the perf trajectory without full measurement time).
fn bench_iters() -> Option<usize> {
    let mut args = std::env::args();
    while let Some(a) = args.next() {
        if a == "--iters" {
            return args.next().and_then(|v| v.parse().ok());
        }
    }
    None
}

fn bencher() -> Bencher {
    match bench_iters() {
        Some(n) => Bencher::bounded(n),
        None => Bencher::default(),
    }
}

/// Report one line and remember it for the JSON artifact.
fn run(results: &mut Vec<(String, Stats)>, name: &str, f: impl FnMut()) -> Stats {
    let st = bencher().run(f);
    report(name, &st);
    results.push((name.to_string(), st.clone()));
    st
}

fn main() {
    println!("=== L3 hot-path microbenchmarks ===\n");
    let mut rng = Rng::new(2024);
    let cfg = SonicConfig::paper_best();
    let mut results: Vec<(String, Stats)> = Vec::new();

    // --- FC compression: svhn fc1792x272 with 50% activation sparsity ---
    let (rows, cols) = (272, 1792);
    let w = ColMatrix::from_row_major(rows, cols, &rng.sparse_vec(rows * cols, 0.5));
    let a = rng.sparse_vec(cols, 0.5);
    run(&mut results, "compress_fc 272x1792 (50% act sparsity)", || {
        black_box(compress_fc(&a, &w));
    });

    let c = compress_fc(&a, &w);
    run(&mut results, "fc_product (compressed matvec)", || {
        black_box(fc_product(&c));
    });

    run(&mut results, "schedule_fc (pass list)", || {
        black_box(schedule_fc(&c, &cfg));
    });

    // --- CONV path: 32x32x56 layer slice, 3x3 kernels ---
    let (h, wdt, cin, cout) = (32, 32, 56, 16);
    let x = rng.sparse_vec(h * wdt * cin, 0.5);
    let kflat: Vec<Vec<f32>> = (0..cout)
        .map(|_| rng.sparse_vec(9 * cin, 0.5))
        .collect();
    let kernels: Vec<CompressedKernel> = kflat
        .iter()
        .map(|k| CompressedKernel::from_dense(k))
        .collect();

    run(&mut results, "extract_patch 3x3x56", || {
        black_box(extract_patch(&x, h, wdt, cin, 16, 16, 3, 3));
    });

    let patch = extract_patch(&x, h, wdt, cin, 16, 16, 3, 3);
    run(&mut results, "compressed_dot x16 kernels", || {
        for k in &kernels {
            black_box(compressed_dot(k, &patch));
        }
    });

    run(&mut results, "conv2d_compressed 32x32x56 -> 16ch", || {
        black_box(conv2d_compressed(&x, h, wdt, cin, &kernels, 3, 3));
    });

    let patches: Vec<Vec<f32>> = (0..64)
        .map(|i| extract_patch(&x, h, wdt, cin, i / 8, i % 8, 3, 3))
        .collect();
    run(&mut results, "schedule_conv 64 px x 16 kernels", || {
        black_box(schedule_conv(&kernels, &patches, &cfg));
    });

    // --- plan compilation, caching, and plan-driven scheduling ---
    println!();
    let svhn = ModelDesc::load_or_builtin("svhn");
    run(&mut results, "ModelPlan::compile (svhn, re-planned)", || {
        black_box(ModelPlan::compile(&svhn, &cfg));
    });
    run(&mut results, "plan::cached (svhn, cache hit)", || {
        black_box(cached(&svhn, &cfg));
    });
    let plan = cached(&svhn, &cfg);
    let fc_plan = plan
        .layers
        .iter()
        .find(|l| !l.is_conv)
        .expect("svhn has FC layers");
    run(&mut results, "schedule_layer (from compiled plan)", || {
        black_box(schedule_layer(fc_plan));
    });

    // --- plan-cached vs re-planned serving on the FC workload ----------
    //
    // A batch of 16 requests through svhn's fc1792x272.  Re-planned: each
    // request rebuilds the compression (kept set + column gather) before
    // the matvec.  Plan-cached: the precompiled FcExec streams the weight
    // matrix once for the whole batch.
    println!();
    const BATCH: usize = 16;
    let batch: Vec<Vec<f32>> = (0..BATCH).map(|_| rng.sparse_vec(cols, 0.5)).collect();
    let replanned = run(
        &mut results,
        "serve FC batch=16 (re-planned per request)",
        || {
            for x in &batch {
                let c = compress_fc(x, &w);
                black_box(fc_product(&c));
            }
        },
    );
    let exec = FcExec::new(w.clone(), false, 0.0);
    let plan_cached = run(
        &mut results,
        "serve FC batch=16 (plan-cached batched kernel)",
        || {
            black_box(exec.forward_batch(&batch).unwrap());
        },
    );
    let speedup = replanned.mean_ns / plan_cached.mean_ns;
    println!(
        "\nplan-cached serving speedup on FC workload: {speedup:.2}x \
         (target >= 2x){}",
        if speedup >= 2.0 { "" } else { "  ** BELOW TARGET **" }
    );

    // --- structurally-sparse kernel micro-bench: all four FC kernels ----
    //
    // The acceptance gate for the compiled sparse kernels: on the
    // svhn-sized FC matrix, compare the dense column-streaming fallback
    // against every compressed kernel (CSC, CSR, bitmap) across weight
    // sparsity x batch size — the 0.5–0.9 *density* band (sparsity
    // 0.1–0.5) plus the legacy sparse corners.  All sides run through
    // `forward_batch_into` with persistent buffers, so the comparison is
    // pure kernel time.  Each cell also scores the cost model: the
    // policy's chosen-kernel time over the measured per-cell best
    // (`policy_vs_oracle`, CI-gated <= 1.05).  Results go to
    // BENCH_kernels.json.
    println!("\n=== kernel micro-bench: dense/csc/csr/bitmap (272x1792 FC) ===\n");
    let mut kernel_entries = Vec::new();
    let mut csc_speedup_gate = 0.0; // 90% sparsity, batch 8 (target >= 2x)
    let mut max_policy_vs_oracle = 0.0f64;
    let policy = KernelPolicy::default();
    for &sparsity in &[0.1f64, 0.2, 0.3, 0.4, 0.5, 0.8, 0.9, 0.95] {
        let wk = ColMatrix::from_row_major(rows, cols, &rng.sparse_vec(rows * cols, sparsity));
        let execs: Vec<FcExec> = KernelChoice::FC_CANDIDATES
            .iter()
            .map(|&k| FcExec::with_kernel(wk.clone(), false, 0.0, k))
            .collect();
        // what the selector would compile for this matrix (exact stats)
        let chosen = policy.choose(&execs[0].stats);
        let chosen_idx = KernelChoice::FC_CANDIDATES
            .iter()
            .position(|&k| k == chosen)
            .expect("chosen kernel is an FC candidate");
        for &bn in &[1usize, 8, 64] {
            let inputs: Vec<Vec<f32>> = (0..bn).map(|_| rng.normal_vec(cols)).collect();
            let (mut xt, mut yt) = (Vec::new(), Vec::new());
            let mut out = BatchTensor::new();
            let times: Vec<f64> = execs
                .iter()
                .zip(KernelChoice::FC_CANDIDATES)
                .map(|(exec, k)| {
                    run(
                        &mut results,
                        &format!("fc {:<6} sp={sparsity:.2} batch={bn}", k.as_str()),
                        || {
                            exec.forward_batch_into(&inputs, &mut xt, &mut yt, &mut out)
                                .unwrap();
                            black_box(&out);
                        },
                    )
                    .mean_ns
                })
                .collect();
            let best_idx = (0..times.len())
                .min_by(|&a, &b| times[a].total_cmp(&times[b]))
                .unwrap();
            let policy_vs_oracle = times[chosen_idx] / times[best_idx];
            max_policy_vs_oracle = max_policy_vs_oracle.max(policy_vs_oracle);
            let csc_speedup = times[0] / times[1];
            println!(
                "    -> policy {} vs oracle {}: {policy_vs_oracle:.2}x  \
                 (csc {csc_speedup:.2}x dense)\n",
                chosen.as_str(),
                KernelChoice::FC_CANDIDATES[best_idx].as_str(),
            );
            if sparsity == 0.9 && bn == 8 {
                csc_speedup_gate = csc_speedup;
            }
            kernel_entries.push(obj(vec![
                ("sparsity", num(sparsity)),
                ("density", num(1.0 - sparsity)),
                ("batch", num(bn as f64)),
                ("dense_ns_per_inf", num(times[0] / bn as f64)),
                ("csc_ns_per_inf", num(times[1] / bn as f64)),
                ("csr_ns_per_inf", num(times[2] / bn as f64)),
                ("bitmap_ns_per_inf", num(times[3] / bn as f64)),
                ("ns_per_inf", num(times[1] / bn as f64)),
                ("speedup_vs_dense", num(csc_speedup)),
                ("chosen_kernel", s(chosen.as_str())),
                (
                    "best_kernel",
                    s(KernelChoice::FC_CANDIDATES[best_idx].as_str()),
                ),
                ("chosen_speedup_vs_dense", num(times[0] / times[chosen_idx])),
                ("policy_vs_oracle", num(policy_vs_oracle)),
            ]));
        }
    }
    println!(
        "CSC kernel speedup at 90% weight sparsity, batch 8: {csc_speedup_gate:.2}x \
         (target >= 2x){}",
        if csc_speedup_gate >= 2.0 { "" } else { "  ** BELOW TARGET **" }
    );
    println!(
        "worst policy_vs_oracle across the grid: {max_policy_vs_oracle:.3} \
         (CI gate <= 1.05){}",
        if max_policy_vs_oracle <= 1.05 { "" } else { "  ** ABOVE GATE **" }
    );
    let kernels_json = obj(vec![
        ("bench", s("kernels")),
        ("rows", num(rows as f64)),
        ("cols", num(cols as f64)),
        ("csc_speedup_90sp_b8", num(csc_speedup_gate)),
        ("max_policy_vs_oracle", num(max_policy_vs_oracle)),
        ("results", arr(kernel_entries)),
    ]);
    let kout = std::env::var("SONIC_BENCH_KERNELS_JSON")
        .unwrap_or_else(|_| "BENCH_kernels.json".to_string());
    match std::fs::write(&kout, kernels_json.to_pretty()) {
        Ok(()) => println!("kernel results written to {kout}"),
        Err(e) => eprintln!("could not write {kout}: {e}"),
    }

    // --- activation-gating micro-bench: gated vs ungated kernels --------
    //
    // Dual-sparsity acceptance: on the same svhn-sized FC matrix, compare
    // the activation-gated kernel variants (skip a stored column when its
    // batch activation slab is all-zero) against the ungated streaming
    // kernels across measured activation sparsity x batch size.  At 0%
    // activation sparsity this measures the pure gating overhead the
    // density policy avoids by running ungated on dense batches; at 90%
    // it measures the win the policy captures.  Results go to
    // BENCH_actgate.json (uploaded with the other BENCH_*.json by CI).
    println!("\n=== activation-gating micro-bench: gated vs ungated (272x1792 FC) ===\n");
    let mut act_entries = Vec::new();
    let mut act_gate_gain = 0.0; // csc kernel, 90% act sparsity, batch 8
    let kernels_under_test = [
        (KernelChoice::Dense, 0.3f64), // near-dense layer -> dense kernel
        (KernelChoice::Csc, 0.8),      // pruned layer -> CSC kernel
    ];
    for &(kernel, wsp) in &kernels_under_test {
        let wk = ColMatrix::from_row_major(rows, cols, &rng.sparse_vec(rows * cols, wsp));
        let exec = FcExec::with_kernel(wk, false, 0.0, kernel);
        for &asp in &[0.0f64, 0.5, 0.9] {
            for &bn in &[1usize, 8, 64] {
                let inputs: Vec<Vec<f32>> =
                    (0..bn).map(|_| rng.sparse_vec(cols, asp)).collect();
                let (mut xt, mut yt) = (Vec::new(), Vec::new());
                let mut out = BatchTensor::new();
                let kname = kernel.as_str();
                let ungated = run(
                    &mut results,
                    &format!("fc {kname} ungated asp={asp:.2} batch={bn}"),
                    || {
                        exec.forward_batch_into_gated(
                            &inputs, &mut xt, &mut yt, &mut out, Some(false),
                        )
                        .unwrap();
                        black_box(&out);
                    },
                );
                let gated = run(
                    &mut results,
                    &format!("fc {kname} gated   asp={asp:.2} batch={bn}"),
                    || {
                        exec.forward_batch_into_gated(
                            &inputs, &mut xt, &mut yt, &mut out, Some(true),
                        )
                        .unwrap();
                        black_box(&out);
                    },
                );
                let speedup = ungated.mean_ns / gated.mean_ns;
                println!(
                    "    -> gating {speedup:.2}x ({:.0} ns/inf gated vs {:.0} ungated)\n",
                    gated.mean_ns / bn as f64,
                    ungated.mean_ns / bn as f64
                );
                if kernel == KernelChoice::Csc && asp == 0.9 && bn == 8 {
                    act_gate_gain = speedup;
                }
                act_entries.push(obj(vec![
                    ("kernel", s(kname)),
                    ("weight_sparsity", num(wsp)),
                    ("act_sparsity", num(asp)),
                    ("batch", num(bn as f64)),
                    ("gated_ns_per_inf", num(gated.mean_ns / bn as f64)),
                    ("ungated_ns_per_inf", num(ungated.mean_ns / bn as f64)),
                    ("speedup_gated_vs_ungated", num(speedup)),
                ]));
            }
        }
    }
    println!(
        "activation-gating gain on the CSC kernel at 90% act sparsity, batch 8: \
         {act_gate_gain:.2}x"
    );
    let actgate_json = obj(vec![
        ("bench", s("actgate")),
        ("rows", num(rows as f64)),
        ("cols", num(cols as f64)),
        ("csc_gate_gain_90asp_b8", num(act_gate_gain)),
        ("results", arr(act_entries)),
    ]);
    let aout = std::env::var("SONIC_BENCH_ACTGATE_JSON")
        .unwrap_or_else(|_| "BENCH_actgate.json".to_string());
    match std::fs::write(&aout, actgate_json.to_pretty()) {
        Ok(()) => println!("activation-gating results written to {aout}"),
        Err(e) => eprintln!("could not write {aout}: {e}"),
    }

    // --- engine facade overhead vs the raw backend ----------------------
    //
    // The `serve::Engine` adds per-request machinery on top of the bare
    // backend call: ticket slot allocation, queue hand-off to a worker
    // thread, dynamic-batch formation, and completion notification.  Track
    // that cost from day one: one iteration pushes 8 requests through the
    // engine (submit + wait) vs one direct `infer_batch` of the same 8
    // inputs on the identical backend (the raw path the Router used to
    // expose to callers).
    println!();
    let mnist = ModelDesc::load_or_builtin("mnist");
    let backend: Arc<PlanBackend> = Arc::new(PlanBackend::synthetic(&mnist, 7));
    let serve_batch: Vec<Vec<f32>> = {
        let mut rng = Rng::new(31);
        (0..8).map(|_| rng.normal_vec(backend.input_len())).collect()
    };
    let raw = run(&mut results, "serve batch=8 (raw backend infer_batch)", || {
        black_box(backend.infer_batch(&serve_batch).unwrap());
    });
    let batch_window = Duration::from_micros(50);
    let engine = Engine::builder()
        .serve_config(ServeConfig {
            max_batch: 8,
            batch_window,
            queue_cap: 1024,
            ..ServeConfig::default()
        })
        .model_desc(mnist.clone(), BackendChoice::Custom(backend.clone()))
        .build()
        .expect("engine build");
    let eng = run(&mut results, "serve batch=8 (engine submit+wait)", || {
        let tickets: Vec<_> = serve_batch
            .iter()
            .map(|x| engine.submit("mnist", x.clone()).unwrap())
            .collect();
        for t in tickets {
            black_box(t.wait().unwrap());
        }
    });
    engine.shutdown();
    let engine_overhead = eng.mean_ns / raw.mean_ns;
    println!(
        "\nengine facade cost on an 8-request burst: {engine_overhead:.2}x the raw \
         backend call (includes the {}us batch window)",
        batch_window.as_micros()
    );

    // --- QoS grid: priority mix x deadline mix under overload ------------
    //
    // Acceptance for the QoS-aware serving stack: a deterministic slow
    // backend (fixed per-batch service time) is driven well past its
    // service rate with a small queue_cap, so the queue sits at capacity
    // the whole run (blocking submits = backpressure).  Across the
    // priority-mix x deadline-mix grid we record per-lane served/shed
    // counts and latency percentiles into BENCH_qos.json.  Gates: under
    // the mixed-priority/no-deadline cell the High lane's p99 must beat
    // the Batch lane's; in the deadline cells expired requests complete
    // as deadline_exceeded (no hung tickets — every submit is waited on)
    // without ever reaching the backend's kernels.
    println!("\n=== QoS grid: priority mix x deadline mix (overloaded engine) ===\n");
    struct SlowBackend {
        inner: NullBackend,
        per_batch: Duration,
    }
    impl InferenceBackend for SlowBackend {
        fn infer_batch(&self, inputs: &[Vec<f32>]) -> sonic::util::err::Result<Vec<Vec<f32>>> {
            std::thread::sleep(self.per_batch);
            self.inner.infer_batch(inputs)
        }
        fn input_len(&self) -> usize {
            self.inner.input_len
        }
    }
    let qos_requests = if bench_iters().is_some() { 96 } else { 384 };
    let per_batch = Duration::from_micros(300);
    let priority_mixes: &[(&str, &[Priority])] = &[
        ("all-normal", &[Priority::Normal]),
        (
            "mixed-1h2n1b",
            &[
                Priority::High,
                Priority::Normal,
                Priority::Normal,
                Priority::Batch,
            ],
        ),
    ];
    let deadline_mixes: &[(&str, f64, Option<Duration>)] = &[
        ("none", 0.0, None),
        ("half-2ms", 0.5, Some(Duration::from_millis(2))),
        ("all-2ms", 1.0, Some(Duration::from_millis(2))),
    ];
    let mut qos_cells = Vec::new();
    let mut high_p99 = Duration::ZERO;
    let mut batch_p99 = Duration::ZERO;
    for &(pmix_name, pmix) in priority_mixes {
        for &(dmix_name, dfrac, dl) in deadline_mixes {
            let engine = Engine::builder()
                .serve_config(ServeConfig {
                    max_batch: 8,
                    batch_window: Duration::from_micros(200),
                    queue_cap: 64,
                    // lanes stay differentiated for the whole (short) run
                    promote_after: Duration::from_millis(250),
                    ..ServeConfig::default()
                })
                .model_desc(
                    mnist.clone(),
                    BackendChoice::Custom(Arc::new(SlowBackend {
                        inner: NullBackend {
                            input_len: 784,
                            n_classes: 10,
                        },
                        per_batch,
                    })),
                )
                .build()
                .expect("qos engine build");
            let input = vec![0.25f32; 784];
            let tickets: Vec<_> = (0..qos_requests)
                .map(|i| {
                    let opts = SubmitOptions {
                        priority: pmix[i % pmix.len()],
                        deadline: if (i as f64 / qos_requests as f64) < dfrac {
                            dl
                        } else {
                            None
                        },
                    };
                    engine
                        .submit_opts("mnist", input.clone(), opts)
                        .expect("submit")
                })
                .collect();
            // every ticket must resolve — served or deadline_exceeded
            let mut served = 0u64;
            let mut shed = 0u64;
            for t in tickets {
                let c = t.wait().expect("ticket resolved");
                if c.served() {
                    served += 1;
                } else {
                    shed += 1;
                }
            }
            engine.shutdown();
            let metrics = engine.metrics();
            let mm = metrics.model("mnist").expect("registered");
            println!(
                "qos cell [{pmix_name:>12} x {dmix_name:>8}]: served {served:>4}  shed {shed:>4}  \
                 p99 {:?}",
                mm.p99
            );
            if pmix_name == "mixed-1h2n1b" && dmix_name == "none" {
                high_p99 = mm.lanes[0].p99;
                batch_p99 = mm.lanes[2].p99;
            }
            let lanes = arr(mm
                .lanes
                .iter()
                .map(|l| {
                    obj(vec![
                        ("lane", s(l.priority.as_str())),
                        ("completed", num(l.completed as f64)),
                        ("shed", num(l.shed as f64)),
                        ("mean_batch", num(l.mean_batch)),
                        ("p50_ns", num(l.p50.as_nanos() as f64)),
                        ("p99_ns", num(l.p99.as_nanos() as f64)),
                    ])
                })
                .collect());
            qos_cells.push(obj(vec![
                ("priority_mix", s(pmix_name)),
                ("deadline_mix", s(dmix_name)),
                ("submitted", num(qos_requests as f64)),
                ("served", num(served as f64)),
                ("shed", num(shed as f64)),
                ("p99_ns", num(mm.p99.as_nanos() as f64)),
                ("lanes", lanes),
            ]));
        }
    }
    let qos_gate = high_p99 < batch_p99;
    println!(
        "\nHigh-lane p99 {high_p99:?} vs Batch-lane p99 {batch_p99:?} under overload: {}",
        if qos_gate { "OK (high < batch)" } else { "** GATE FAILED **" }
    );
    let qos_json = obj(vec![
        ("bench", s("qos")),
        ("requests_per_cell", num(qos_requests as f64)),
        ("per_batch_service_us", num(per_batch.as_micros() as f64)),
        ("queue_cap", num(64.0)),
        ("high_p99_ns", num(high_p99.as_nanos() as f64)),
        ("batch_p99_ns", num(batch_p99.as_nanos() as f64)),
        ("high_p99_lt_batch_p99", num(if qos_gate { 1.0 } else { 0.0 })),
        ("cells", arr(qos_cells)),
    ]);
    let qout = std::env::var("SONIC_BENCH_QOS_JSON")
        .unwrap_or_else(|_| "BENCH_qos.json".to_string());
    match std::fs::write(&qout, qos_json.to_pretty()) {
        Ok(()) => println!("QoS grid results written to {qout}"),
        Err(e) => eprintln!("could not write {qout}: {e}"),
    }

    // --- Cluster chaos grid: availability under replica faults ----------
    //
    // Acceptance for the fault-tolerant cluster: 3 replicas of the same
    // slow backend, a paced request stream, and a deterministic fault on
    // replica 1 in the middle of the run.  Cells: healthy baseline,
    // kill-1-of-3 (backend errors instantly; retries fail over), and
    // stall-1-of-3 (backend blocks; per-try timeouts abandon and re-queue
    // the tries).  Gates (checked in CI from BENCH_cluster.json): every
    // ticket resolves (hung == 0), kill-cell availability >= 99%, retry
    // amplification < 1.5x, and energy rolls up only executed work.
    println!("\n=== Cluster chaos grid: availability under replica faults ===\n");
    let creq: usize = if bench_iters().is_some() { 150 } else { 600 };
    let pace = Duration::from_micros(500);
    let window = pace * creq as u32;
    let fault_at = window.mul_f64(0.25);
    let fault_dur = window.mul_f64(0.35);
    let chaos_specs: Vec<(&str, ChaosSpec)> = vec![
        ("healthy", ChaosSpec::none()),
        (
            "kill-1of3",
            ChaosSpec {
                events: vec![ChaosEvent {
                    at: fault_at,
                    replica: 1,
                    kind: FaultKind::Kill {
                        dur: Some(fault_dur),
                    },
                }],
            },
        ),
        (
            "stall-1of3",
            ChaosSpec {
                events: vec![ChaosEvent {
                    at: fault_at,
                    replica: 1,
                    kind: FaultKind::Stall { dur: fault_dur },
                }],
            },
        ),
    ];
    let mut chaos_cells = Vec::new();
    let mut healthy_ppw = 0.0f64;
    let mut kill_gate = (1.0f64, 0u64, 1.0f64); // (availability, hung, retry_amp)
    for (cell_name, chaos) in chaos_specs {
        let cluster = ClusterEngine::build_with(
            mnist.clone(),
            ClusterConfig {
                replicas: 3,
                serve: ServeConfig {
                    max_batch: 8,
                    batch_window: Duration::from_micros(200),
                    queue_cap: 256,
                    promote_after: Duration::from_millis(250),
                    ..ServeConfig::default()
                },
                retry: RetryPolicy {
                    // well under the stall duration so stalled tries are
                    // abandoned and re-queued, not waited out
                    per_try_timeout: Duration::from_millis(10),
                    base_backoff: Duration::from_micros(500),
                    max_backoff: Duration::from_millis(5),
                    ..RetryPolicy::default()
                },
                health: HealthPolicy {
                    probe_interval: Duration::from_millis(10),
                    probe_timeout: Duration::from_millis(50),
                    ..HealthPolicy::default()
                },
                chaos,
                ..ClusterConfig::default()
            },
            |_| {
                Arc::new(SlowBackend {
                    inner: NullBackend {
                        input_len: 784,
                        n_classes: 10,
                    },
                    per_batch,
                }) as Arc<dyn InferenceBackend>
            },
        )
        .expect("cluster build");
        let input = vec![0.25f32; 784];
        let t0 = std::time::Instant::now();
        let mut tickets = Vec::with_capacity(creq);
        let mut in_window = vec![false; creq];
        for i in 0..creq {
            let due = pace * i as u32;
            let now = t0.elapsed();
            if now < due {
                std::thread::sleep(due - now);
            }
            let off = t0.elapsed();
            // the fault window plus half a duration of recovery tail
            in_window[i] = off >= fault_at && off <= fault_at + fault_dur + fault_dur / 2;
            tickets.push(cluster.submit("mnist", input.clone()).expect("cluster submit"));
        }
        // watchdogged waits: every ticket must resolve well within the
        // bound — a None here is a hung ticket, the cardinal sin
        let mut served = 0u64;
        let mut replica_failed = 0u64;
        let mut hung = 0u64;
        let mut window_hist = sonic::serve::LatencyHistogram::default();
        for (i, t) in tickets.iter().enumerate() {
            match t.wait_timeout(Duration::from_secs(5)) {
                Ok(Some(c)) if c.served() => {
                    served += 1;
                    if in_window[i] {
                        window_hist.record(c.wall_latency);
                    }
                }
                Ok(Some(_)) => replica_failed += 1,
                Ok(None) => hung += 1,
                Err(_) => replica_failed += 1,
            }
        }
        cluster.shutdown();
        let m = cluster.metrics();
        let ppw = m.photonic_fps_per_watt();
        if cell_name == "healthy" {
            healthy_ppw = ppw;
        }
        if cell_name == "kill-1of3" {
            kill_gate = (m.availability(), hung, m.retry_amplification());
        }
        let ppw_vs_healthy = if healthy_ppw > 0.0 { ppw / healthy_ppw } else { 0.0 };
        println!(
            "chaos cell [{cell_name:>10}]: served {served:>4}  failed {replica_failed:>3}  hung {hung}  \
             avail {:.4}  retries {:<4} failovers {:<4} amp {:.3}  window p99 {:?}  ppw {:.3}x",
            m.availability(),
            m.retries,
            m.failovers,
            m.retry_amplification(),
            window_hist.quantile(0.99),
            ppw_vs_healthy,
        );
        let replicas_json = arr(m
            .replicas
            .iter()
            .map(|r| {
                obj(vec![
                    ("index", num(r.index as f64)),
                    ("health", s(r.health.as_str())),
                    ("tries", num(r.tries as f64)),
                    ("failures", num(r.failures as f64)),
                    ("probes", num(r.probes as f64)),
                    ("time_degraded_s", num(r.time_degraded.as_secs_f64())),
                    ("time_dead_s", num(r.time_dead.as_secs_f64())),
                    ("photonic_energy_j", num(r.serve.photonic_energy_j)),
                ])
            })
            .collect());
        chaos_cells.push(obj(vec![
            ("cell", s(cell_name)),
            ("submitted", num(creq as f64)),
            ("served", num(served as f64)),
            ("replica_failed", num(replica_failed as f64)),
            ("hung", num(hung as f64)),
            ("availability", num(m.availability())),
            ("retries", num(m.retries as f64)),
            ("failovers", num(m.failovers as f64)),
            ("retry_amplification", num(m.retry_amplification())),
            ("window_p99_ns", num(window_hist.quantile(0.99).as_nanos() as f64)),
            ("p99_ns", num(m.p99.as_nanos() as f64)),
            ("fps_per_watt", num(ppw)),
            ("ppw_vs_healthy", num(ppw_vs_healthy)),
            ("photonic_energy_j", num(m.serve.photonic_energy_j)),
            ("replicas", replicas_json),
        ]));
    }
    let (kill_avail, kill_hung, kill_amp) = kill_gate;
    println!(
        "\nkill-1of3 gates: availability {kill_avail:.4} (>= 0.99), hung {kill_hung} (== 0), \
         retry amplification {kill_amp:.3} (< 1.5)"
    );
    let cluster_json = obj(vec![
        ("bench", s("cluster_chaos")),
        ("requests_per_cell", num(creq as f64)),
        ("replicas", num(3.0)),
        ("pace_us", num(pace.as_micros() as f64)),
        ("fault_at_us", num(fault_at.as_micros() as f64)),
        ("fault_dur_us", num(fault_dur.as_micros() as f64)),
        ("kill_availability", num(kill_avail)),
        ("kill_hung", num(kill_hung as f64)),
        ("kill_retry_amplification", num(kill_amp)),
        ("cells", arr(chaos_cells)),
    ]);
    let cout = std::env::var("SONIC_BENCH_CLUSTER_JSON")
        .unwrap_or_else(|_| "BENCH_cluster.json".to_string());
    match std::fs::write(&cout, cluster_json.to_pretty()) {
        Ok(()) => println!("cluster chaos grid results written to {cout}"),
        Err(e) => eprintln!("could not write {cout}: {e}"),
    }

    // --- analytic simulator (the figure generator's inner loop) ---
    println!();
    for name in ["mnist", "cifar10", "stl10", "svhn"] {
        let desc = ModelDesc::load_or_builtin(name);
        run(&mut results, &format!("simulate({name})"), || {
            black_box(simulate(&desc, &cfg));
        });
    }

    // --- JSON artifact for the perf trajectory --------------------------
    let json = obj(vec![
        ("bench", s("hotpath")),
        ("plan_cached_fc_speedup", num(speedup)),
        ("engine_overhead_vs_raw", num(engine_overhead)),
        ("batch", num(BATCH as f64)),
        (
            "results",
            arr(results
                .iter()
                .map(|(name, st)| {
                    obj(vec![
                        ("name", s(name)),
                        ("mean_ns", num(st.mean_ns)),
                        ("median_ns", num(st.median_ns)),
                        ("p95_ns", num(st.p95_ns)),
                        ("samples", num(st.samples as f64)),
                    ])
                })
                .collect()),
        ),
    ]);
    let out = std::env::var("SONIC_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    match std::fs::write(&out, json.to_pretty()) {
        Ok(()) => println!("\nresults written to {out}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
