//! Bench: L3 coordinator hot paths — the code that runs per request in a
//! real deployment: FC compression, CONV patch extraction + compressed
//! dot products, VDU scheduling, and the analytic simulator itself.
//! This is the primary input to the §Perf optimization loop.

use sonic::arch::SonicConfig;
use sonic::coordinator::compress::{compress_fc, fc_product};
use sonic::coordinator::convflow::{
    compressed_dot, conv2d_compressed, extract_patch, CompressedKernel,
};
use sonic::coordinator::schedule::{schedule_conv, schedule_fc};
use sonic::model::ModelDesc;
use sonic::sim::simulate;
use sonic::sparsity::ColMatrix;
use sonic::util::bench::{black_box, report, Bencher};
use sonic::util::rng::Rng;

fn main() {
    println!("=== L3 hot-path microbenchmarks ===\n");
    let mut rng = Rng::new(2024);
    let cfg = SonicConfig::paper_best();

    // --- FC compression: svhn fc1792x272 with 50% activation sparsity ---
    let (rows, cols) = (272, 1792);
    let w = ColMatrix::from_row_major(rows, cols, &rng.sparse_vec(rows * cols, 0.5));
    let a = rng.sparse_vec(cols, 0.5);
    let st = Bencher::default().run(|| {
        black_box(compress_fc(&a, &w));
    });
    report("compress_fc 272x1792 (50% act sparsity)", &st);

    let c = compress_fc(&a, &w);
    let st = Bencher::default().run(|| {
        black_box(fc_product(&c));
    });
    report("fc_product (compressed matvec)", &st);

    let st = Bencher::default().run(|| {
        black_box(schedule_fc(&c, &cfg));
    });
    report("schedule_fc (pass list)", &st);

    // --- CONV path: 32x32x56 layer slice, 3x3 kernels ---
    let (h, wdt, cin, cout) = (32, 32, 56, 16);
    let x = rng.sparse_vec(h * wdt * cin, 0.5);
    let kflat: Vec<Vec<f32>> = (0..cout)
        .map(|_| rng.sparse_vec(9 * cin, 0.5))
        .collect();
    let kernels: Vec<CompressedKernel> = kflat
        .iter()
        .map(|k| CompressedKernel::from_dense(k))
        .collect();

    let st = Bencher::default().run(|| {
        black_box(extract_patch(&x, h, wdt, cin, 16, 16, 3, 3));
    });
    report("extract_patch 3x3x56", &st);

    let patch = extract_patch(&x, h, wdt, cin, 16, 16, 3, 3);
    let st = Bencher::default().run(|| {
        for k in &kernels {
            black_box(compressed_dot(k, &patch));
        }
    });
    report("compressed_dot x16 kernels", &st);

    let st = Bencher::default().run(|| {
        black_box(conv2d_compressed(&x, h, wdt, cin, &kernels, 3, 3));
    });
    report("conv2d_compressed 32x32x56 -> 16ch", &st);

    let patches: Vec<Vec<f32>> = (0..64)
        .map(|i| extract_patch(&x, h, wdt, cin, i / 8, i % 8, 3, 3))
        .collect();
    let st = Bencher::default().run(|| {
        black_box(schedule_conv(&kernels, &patches, &cfg));
    });
    report("schedule_conv 64 px x 16 kernels", &st);

    // --- analytic simulator (the figure generator's inner loop) ---
    println!();
    for name in ["mnist", "cifar10", "stl10", "svhn"] {
        let desc = ModelDesc::load_or_builtin(name);
        let st = Bencher::default().run(|| {
            black_box(simulate(&desc, &cfg));
        });
        report(&format!("simulate({name})"), &st);
    }
}
