//! Bench: Table 3 — sparsification + clustering results.
//!
//! Prints the paper's Table-3 targets next to the measured values from the
//! real sparsity-aware training run (`artifacts/table3.json`, when built),
//! asserting the surviving-parameter totals land within 1%.

use sonic::model::ModelDesc;
use sonic::util::bench::Table;
use sonic::util::json::Json;

fn main() {
    println!("=== Table 3: summary of sparsification and clustering ===\n");
    let paper: &[(&str, usize, usize, usize, f64)] = &[
        // model, layers pruned, clusters, surviving params, accuracy
        ("mnist", 4, 64, 749_365, 92.89),
        ("cifar10", 7, 16, 276_437, 86.86),
        ("stl10", 5, 64, 46_672_643, 75.2),
        ("svhn", 5, 64, 331_417, 95.0),
    ];

    let art = sonic::artifacts_dir();
    let measured = std::fs::read_to_string(art.join("table3.json"))
        .ok()
        .and_then(|s| Json::parse(&s).ok());

    let mut t = Table::new(&[
        "dataset",
        "layers pruned",
        "clusters",
        "params (paper)",
        "params (measured)",
        "acc paper",
        "acc ours (synthetic)",
    ]);
    for &(name, layers, clusters, params, acc) in paper {
        let (m_params, m_acc) = measured
            .as_ref()
            .and_then(|j| j.as_arr())
            .and_then(|rows| {
                rows.iter().find(|r| {
                    r.get("model").and_then(|v| v.as_str()) == Some(name)
                })
            })
            .map(|r| {
                (
                    r.get("surviving_params")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(0),
                    r.get("accuracy_synthetic")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.0),
                )
            })
            .unwrap_or((0, 0.0));
        if m_params > 0 {
            let err = (m_params as f64 - params as f64).abs() / params as f64;
            assert!(err < 0.01, "{name}: measured {m_params} vs paper {params}");
        }
        t.row(&[
            name.into(),
            layers.to_string(),
            clusters.to_string(),
            params.to_string(),
            if m_params > 0 {
                m_params.to_string()
            } else {
                "(run `make artifacts`)".into()
            },
            format!("{acc}%"),
            if m_params > 0 {
                format!("{m_acc:.2}%")
            } else {
                "-".into()
            },
        ]);
    }
    t.print();

    // Builtin descriptors carry Table-3 values; verify DAC sizing logic.
    println!("\n--- DAC-resolution consequence (the point of clustering) ---");
    for &(name, _, clusters, ..) in paper {
        let d = ModelDesc::load_or_builtin(name);
        assert!(d.n_clusters <= 64, "{name}");
        // cifar10's 16 clusters need only 4 bits; the architecture
        // provisions 6-bit DACs for the 64-cluster worst case (§V.A).
        assert!(d.weight_dac_bits <= 6, "{name}: clusters must fit 6-bit DACs");
        println!(
            "  {name:8}: {clusters} clusters -> {}-bit (SONIC provisions 6-bit DACs, 3 mW vs 40 mW)",
            d.weight_dac_bits
        );
    }
}
