//! Bench: Fig. 7 — layer-wise weight & activation sparsity across the four
//! models.
//!
//! Uses the measured values from the sparsity-aware training run
//! (`artifacts/<model>.json`) when available, falling back to the builtin
//! Table-3-derived descriptors.  Asserts the figure's qualitative shape:
//! pruned layers carry substantial weight sparsity, and ReLU produces
//! non-trivial activation sparsity in the interior layers.

use sonic::model::ModelDesc;
use sonic::sparsity::stats::{fig7_rows, model_avg_sparsity};
use sonic::util::bench::Table;

fn main() {
    println!("=== Fig. 7: sparsity across layers, four models ===\n");
    for name in ["mnist", "cifar10", "stl10", "svhn"] {
        let d = ModelDesc::load_or_builtin(name);
        let rows = fig7_rows(&d);
        let mut t = Table::new(&["layer", "weight sparsity", "act sparsity", "unique weights"]);
        for r in &rows {
            t.row(&[
                r.layer.clone(),
                format!("{:.1}%", r.weight_sparsity * 100.0),
                format!("{:.1}%", r.act_sparsity * 100.0),
                r.unique_weights.to_string(),
            ]);
        }
        println!("--- {name} ---");
        t.print();
        let (avg_w, avg_a) = model_avg_sparsity(&d);
        println!(
            "model averages: weight {:.1}%, activation {:.1}%\n",
            avg_w * 100.0,
            avg_a * 100.0
        );

        // Shape: some layer is substantially pruned; interior activation
        // sparsity exists (ReLU); codebooks respect the cluster budget.
        assert!(
            rows.iter().any(|r| r.weight_sparsity > 0.25),
            "{name}: no meaningfully pruned layer"
        );
        assert!(
            rows.iter().skip(1).any(|r| r.act_sparsity > 0.1),
            "{name}: no activation sparsity past the input layer"
        );
        assert!(
            rows.iter().all(|r| r.unique_weights <= d.n_clusters),
            "{name}: codebook exceeded"
        );
    }
    println!("shape checks passed for all four models");
}
