//! Bench: Fig. 10 — energy per bit (EPB) across the accelerator platforms,
//! plus the paper's headline average ratios: SONIC is 8.4x lower than
//! NullHop, 5.78x RSNN, 19.4x LightBulb, 18.4x CrossLight, 27.6x HolyLight.

use sonic::arch::SonicConfig;
use sonic::baselines::all_platforms;
use sonic::model::ModelDesc;
use sonic::sim::simulate;
use sonic::util::bench::{black_box, report, Bencher, Table};
use sonic::util::si;

fn main() {
    println!("=== Fig. 10: energy-per-bit comparison ===\n");
    let cfg = SonicConfig::paper_best();
    let platforms = all_platforms();
    let models = ["mnist", "cifar10", "stl10", "svhn"];

    let mut headers = vec!["model".to_string(), "SONIC".to_string()];
    headers.extend(platforms.iter().map(|p| p.name().to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);

    for name in models {
        let desc = ModelDesc::load_or_builtin(name);
        let sonic = simulate(&desc, &cfg);
        let mut row = vec![name.to_string(), si(sonic.epb_j, "J/b")];
        for p in &platforms {
            row.push(si(p.evaluate(&desc).epb_j, "J/b"));
        }
        t.row(&row);
    }
    t.print();

    println!("\n--- average ratios (platform EPB / SONIC EPB; paper in brackets) ---");
    let targets = [
        ("NullHop", 8.4),
        ("RSNN", 5.78),
        ("LightBulb", 19.4),
        ("CrossLight", 18.4),
        ("HolyLight", 27.6),
    ];
    for (pname, want) in targets {
        let p = platforms.iter().find(|p| p.name() == pname).unwrap();
        let mut prod = 1.0;
        for name in models {
            let desc = ModelDesc::load_or_builtin(name);
            let s = simulate(&desc, &cfg);
            prod *= p.evaluate(&desc).epb_j / s.epb_j;
        }
        let gm: f64 = prod.powf(1.0 / models.len() as f64);
        let ok = (gm / want - 1.0).abs() < 0.25;
        println!("  {pname:<11} / SONIC: {gm:6.2}x   [paper {want}x]  {}",
                 if ok { "OK" } else { "OUT OF BAND" });
        assert!(ok, "{pname}: EPB ratio {gm} vs paper {want}");
        assert!(gm > 1.0, "{pname}: SONIC must have lower EPB");
    }

    println!("\n--- timing ---");
    let desc = ModelDesc::load_or_builtin("mnist");
    let st = Bencher::default().run(|| {
        black_box(simulate(&desc, &cfg).epb_j);
    });
    report("simulate(mnist) -> EPB", &st);
}
