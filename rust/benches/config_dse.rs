//! Bench: §V.B architecture DSE — the paper reports (n, m, N, K) =
//! (5, 50, 50, 10) as the best configuration and notes that raising n
//! beyond 5 brings no benefit because dense kernel vectors never exceed
//! ~5 entries after sparsification.

use sonic::model::ModelDesc;
use sonic::sim::dse::{evaluate, explore, DseGrid};
use sonic::util::bench::{black_box, report, Bencher, Table};
use sonic::util::si;

fn main() {
    println!("=== §V.B: (n, m, N, K) design-space exploration ===\n");
    let models: Vec<ModelDesc> = ["mnist", "cifar10", "stl10", "svhn"]
        .iter()
        .map(|n| ModelDesc::load_or_builtin(n))
        .collect();

    let points = explore(&models, None);
    let mut t = Table::new(&["rank", "n", "m", "N", "K", "FPS/W (gm)", "EPB (gm)", "power"]);
    for (i, p) in points.iter().take(12).enumerate() {
        t.row(&[
            (i + 1).to_string(),
            p.n.to_string(),
            p.m.to_string(),
            p.n_conv_vdus.to_string(),
            p.n_fc_vdus.to_string(),
            format!("{:.1}", p.gm_fps_per_watt),
            si(p.gm_epb, "J/b"),
            format!("{:.1} W", p.mean_power_w),
        ]);
    }
    t.print();
    println!("\ntop geometry: {:?} (paper best: (5, 50, 50, 10))", points[0].geometry());

    // Paper claim: n > 5 gives no benefit.  (The workload is non-empty,
    // so `evaluate` always scores — it returns None only for an empty
    // model slice.)
    let at5 = evaluate(&models, 5, 50, 50, 10).expect("non-empty workload");
    let at8 = evaluate(&models, 8, 50, 50, 10).expect("non-empty workload");
    let at10 = evaluate(&models, 10, 50, 50, 10).expect("non-empty workload");
    println!(
        "\nn sweep @ (_, 50, 50, 10): n=5 {:.1}, n=8 {:.1}, n=10 {:.1} FPS/W",
        at5.gm_fps_per_watt, at8.gm_fps_per_watt, at10.gm_fps_per_watt
    );
    assert!(
        at8.gm_fps_per_watt <= at5.gm_fps_per_watt * 1.02
            && at10.gm_fps_per_watt <= at5.gm_fps_per_watt * 1.02,
        "raising n beyond 5 must not help"
    );

    // The paper-best point must rank near the top of the swept grid.
    let rank = points
        .iter()
        .position(|p| p.geometry() == (5, 50, 50, 10))
        .expect("paper point in grid");
    println!("paper geometry rank in sweep: {} / {}", rank + 1, points.len());
    assert!(rank < points.len() / 4, "paper point must rank in top quartile");

    println!("\n--- timing ---");
    let st = Bencher::default().run(|| {
        black_box(evaluate(&models, 5, 50, 50, 10));
    });
    report("dse::evaluate (4 models)", &st);
    let grid = DseGrid {
        n: vec![5],
        m: vec![25, 50],
        n_conv: vec![50],
        k_fc: vec![10],
    };
    let st = Bencher::quick().run(|| {
        black_box(explore(&models, Some(grid.clone())));
    });
    report("dse::explore (2-point grid)", &st);
}
