//! `sonic::serve::cluster` integration tests: replicated serving,
//! deterministic fault injection, retry/failover, health state machine,
//! and the executed-work-only energy pin.
//!
//! Every wait in the fault tests is watchdogged (`wait_timeout`) — a
//! ticket that fails to resolve is a test failure, never a hang.

use std::sync::Arc;
use std::time::{Duration, Instant};

use sonic::model::ModelDesc;
use sonic::serve::cluster::chaos::parse_duration;
use sonic::serve::cluster::{
    ChaosEvent, ChaosSpec, ClusterConfig, ClusterEngine, FaultKind, Health, HealthPolicy,
    HealthTracker, RetryPolicy,
};
use sonic::serve::{InferenceBackend, NullBackend, Outcome, ServeConfig};
use sonic::util::err::Result;

/// Watchdog bound: no single ticket may take longer than this to
/// resolve, even with replicas dying under it.
const WATCHDOG: Duration = Duration::from_secs(10);

fn mnist() -> ModelDesc {
    ModelDesc::builtin("mnist").unwrap()
}

/// Backend with a fixed per-batch service time (so faults land while
/// work is genuinely in flight).
struct SlowBackend {
    inner: NullBackend,
    per_batch: Duration,
}

impl InferenceBackend for SlowBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.per_batch);
        self.inner.infer_batch(inputs)
    }
    fn input_len(&self) -> usize {
        self.inner.input_len()
    }
}

fn null_factory() -> impl Fn(usize) -> Arc<dyn InferenceBackend> {
    |_| {
        Arc::new(NullBackend {
            input_len: 784,
            n_classes: 10,
        }) as Arc<dyn InferenceBackend>
    }
}

fn slow_factory(per_batch: Duration) -> impl Fn(usize) -> Arc<dyn InferenceBackend> {
    move |_| {
        Arc::new(SlowBackend {
            inner: NullBackend {
                input_len: 784,
                n_classes: 10,
            },
            per_batch,
        }) as Arc<dyn InferenceBackend>
    }
}

/// Small batches, short windows: keep the tests fast.
fn fast_serve() -> ServeConfig {
    ServeConfig {
        max_batch: 4,
        batch_window: Duration::from_micros(200),
        queue_cap: 256,
        ..ServeConfig::default()
    }
}

/// Tight retry knobs so failover happens in milliseconds, not seconds.
fn fast_retry() -> RetryPolicy {
    RetryPolicy {
        per_try_timeout: Duration::from_millis(25),
        base_backoff: Duration::from_micros(500),
        max_backoff: Duration::from_millis(5),
        ..RetryPolicy::default()
    }
}

// ---- spec / policy unit tests ----------------------------------------------

#[test]
fn chaos_spec_parses_the_full_grammar() {
    let spec =
        ChaosSpec::parse("kill@200ms:r1:dur=400ms, stall@1s:r0:dur=500us; slow@3s:r2:x=4").unwrap();
    assert_eq!(
        spec.events,
        vec![
            ChaosEvent {
                at: Duration::from_millis(200),
                replica: 1,
                kind: FaultKind::Kill {
                    dur: Some(Duration::from_millis(400)),
                },
            },
            ChaosEvent {
                at: Duration::from_secs(1),
                replica: 0,
                kind: FaultKind::Stall {
                    dur: Duration::from_micros(500),
                },
            },
            ChaosEvent {
                at: Duration::from_secs(3),
                replica: 2,
                kind: FaultKind::Slow {
                    mult: 4.0,
                    dur: None,
                },
            },
        ]
    );
    // permanent kill: no dur
    let perm = ChaosSpec::parse("kill@0ms:r0").unwrap();
    assert_eq!(perm.events[0].kind, FaultKind::Kill { dur: None });
    assert!(ChaosSpec::parse("").unwrap().is_empty());
}

#[test]
fn chaos_spec_rejects_malformed_events() {
    for bad in [
        "kill200ms:r1",          // no @
        "kill@banana:r1",        // bad time
        "kill@1s",               // no replica
        "kill@1s:x1",            // replica must be rN
        "stall@1s:r0",           // stall requires dur
        "slow@1s:r0",            // slow requires x
        "slow@1s:r0:x=0.5",      // mult < 1
        "freeze@1s:r0",          // unknown kind
        "kill@1s:r0:whoops=3ms", // unknown field
    ] {
        assert!(ChaosSpec::parse(bad).is_err(), "accepted {bad:?}");
    }
}

#[test]
fn duration_grammar_accepts_suffixes_and_bare_ms() {
    assert_eq!(parse_duration("200ms"), Some(Duration::from_millis(200)));
    assert_eq!(parse_duration("1.5s"), Some(Duration::from_micros(1_500_000)));
    assert_eq!(parse_duration("500us"), Some(Duration::from_micros(500)));
    assert_eq!(parse_duration("250"), Some(Duration::from_millis(250)));
    assert_eq!(parse_duration(" 10ms "), Some(Duration::from_millis(10)));
    assert_eq!(parse_duration("-5ms"), None);
    assert_eq!(parse_duration("banana"), None);
    assert_eq!(parse_duration(""), None);
}

#[test]
fn backoff_doubles_caps_and_respects_the_deadline() {
    let p = RetryPolicy {
        base_backoff: Duration::from_millis(2),
        max_backoff: Duration::from_millis(50),
        ..RetryPolicy::default()
    };
    assert_eq!(p.backoff_for(1, None), Duration::from_millis(2));
    assert_eq!(p.backoff_for(2, None), Duration::from_millis(4));
    assert_eq!(p.backoff_for(3, None), Duration::from_millis(8));
    // ceiling
    assert_eq!(p.backoff_for(10, None), Duration::from_millis(50));
    // huge try counts must not overflow the shift
    assert_eq!(p.backoff_for(u32::MAX, None), Duration::from_millis(50));
    // deadline-aware: never sleep past the remaining budget
    assert_eq!(
        p.backoff_for(3, Some(Duration::from_millis(1))),
        Duration::from_millis(1)
    );
}

#[test]
fn health_tracker_walks_the_state_machine() {
    let policy = HealthPolicy {
        degraded_after: 2,
        dead_after: 4,
        rewarm_successes: 3,
        ..HealthPolicy::default()
    };
    let t = HealthTracker::new();
    assert_eq!(t.health(), Health::Healthy);

    // one failure is forgiven
    t.record_failure(&policy);
    assert_eq!(t.health(), Health::Healthy);
    // second consecutive failure demotes
    t.record_failure(&policy);
    assert_eq!(t.health(), Health::Degraded);
    // pile on to Dead
    t.record_failure(&policy);
    t.record_failure(&policy);
    assert_eq!(t.health(), Health::Dead);
    // more failures keep it Dead (demote-only)
    t.record_failure(&policy);
    assert_eq!(t.health(), Health::Dead);

    // first success re-enters Degraded, never straight to Healthy
    t.record_success(&policy);
    assert_eq!(t.health(), Health::Degraded);
    // re-warm streak: needs rewarm_successes total in Degraded
    t.record_success(&policy);
    assert_eq!(t.health(), Health::Degraded);
    t.record_success(&policy);
    assert_eq!(t.health(), Health::Healthy);

    // a failure mid-rewarm resets the streak
    t.record_failure(&policy);
    t.record_failure(&policy);
    assert_eq!(t.health(), Health::Degraded);
    t.record_success(&policy);
    t.record_failure(&policy); // streak broken
    t.record_success(&policy);
    t.record_success(&policy);
    assert_eq!(t.health(), Health::Degraded, "streak must restart after a failure");
    t.record_success(&policy);
    assert_eq!(t.health(), Health::Healthy);

    let (_, deg, dead, transitions) = t.snapshot();
    assert!(deg > Duration::ZERO);
    assert!(dead > Duration::ZERO);
    assert!(transitions >= 4);
}

// ---- healthy-cluster integration -------------------------------------------

#[test]
fn healthy_cluster_serves_and_rolls_up_replica_metrics() {
    let cluster = ClusterEngine::build_with(
        mnist(),
        ClusterConfig {
            replicas: 3,
            serve: fast_serve(),
            ..ClusterConfig::default()
        },
        null_factory(),
    )
    .unwrap();
    assert_eq!(cluster.models(), vec!["mnist".to_string()]);
    assert_eq!(cluster.input_len("mnist").unwrap(), 784);

    // one-hot inputs: NullBackend puts logit 1.0 at j % 10, proving each
    // cluster ticket carried *its own* request through routing
    let n = 40usize;
    let tickets: Vec<_> = (0..n)
        .map(|j| {
            let mut x = vec![0.0f32; 784];
            x[j] = 1.0;
            cluster.submit("mnist", x).unwrap()
        })
        .collect();
    for (j, t) in tickets.iter().enumerate() {
        let c = t
            .wait_timeout(WATCHDOG)
            .unwrap()
            .expect("healthy cluster must resolve within the watchdog");
        assert_eq!(c.outcome, Outcome::Served);
        assert_eq!(c.argmax, j % 10, "ticket {j} got another request's logits");
        assert_eq!(c.id, t.id());
    }
    cluster.shutdown();

    let m = cluster.metrics();
    assert_eq!(m.completed, n as u64);
    assert_eq!(m.replica_failed, 0);
    assert_eq!(m.deadline_exceeded, 0);
    assert!((m.availability() - 1.0).abs() < 1e-12);
    assert_eq!(m.replicas.len(), 3);
    assert!(m.replicas.iter().all(|r| r.health == Health::Healthy));
    // the rollup is exactly the sum of the replicas
    let sum_completed: u64 = m.replicas.iter().map(|r| r.serve.completed).sum();
    assert_eq!(sum_completed, n as u64);
    let sum_energy: f64 = m.replicas.iter().map(|r| r.serve.photonic_energy_j).sum();
    assert!(m.serve.photonic_energy_j > 0.0, "plan charging must be live");
    assert!(
        (m.serve.photonic_energy_j - sum_energy).abs() <= 1e-12 * sum_energy.max(1.0),
        "cluster energy {} != sum of replica energies {}",
        m.serve.photonic_energy_j,
        sum_energy
    );
}

#[test]
fn cluster_rejects_unknown_model_and_bad_input_len() {
    let cluster = ClusterEngine::build_with(
        mnist(),
        ClusterConfig {
            replicas: 2,
            serve: fast_serve(),
            ..ClusterConfig::default()
        },
        null_factory(),
    )
    .unwrap();
    assert!(cluster.submit("nope", vec![0.0; 784]).is_err());
    assert!(cluster.submit("mnist", vec![0.0; 3]).is_err());
    assert!(cluster.input_len("nope").is_err());
    cluster.shutdown();
    assert!(cluster.is_stopping());
    assert!(
        cluster.submit("mnist", vec![0.0; 784]).is_err(),
        "submits after shutdown must be refused"
    );
}

// ---- fault injection --------------------------------------------------------

#[test]
fn kill_one_of_three_mid_load_every_ticket_resolves() {
    let cluster = ClusterEngine::build_with(
        mnist(),
        ClusterConfig {
            replicas: 3,
            serve: fast_serve(),
            retry: fast_retry(),
            health: HealthPolicy {
                probe_interval: Duration::from_millis(5),
                probe_timeout: Duration::from_millis(50),
                ..HealthPolicy::default()
            },
            ..ClusterConfig::default()
        },
        slow_factory(Duration::from_micros(200)),
    )
    .unwrap();
    let n = 120usize;
    let mut tickets = Vec::with_capacity(n);
    for i in 0..n {
        if i == n / 4 {
            // the fault lands mid-load, with tries in flight
            cluster.fault(1).kill();
        }
        if i == 3 * n / 4 {
            cluster.fault(1).revive();
        }
        tickets.push(cluster.submit("mnist", vec![0.25; 784]).unwrap());
        std::thread::sleep(Duration::from_micros(200));
    }
    let mut served = 0u64;
    let mut failed = 0u64;
    for t in &tickets {
        match t.wait_timeout(WATCHDOG).unwrap() {
            Some(c) if c.served() => served += 1,
            Some(_) => failed += 1,
            None => panic!("hung ticket {} — watchdog fired", t.id()),
        }
    }
    cluster.shutdown();
    let m = cluster.metrics();
    assert_eq!(served + failed, n as u64, "every ticket must resolve");
    assert_eq!(m.resolved(), n as u64);
    assert!(
        m.availability() >= 0.99,
        "kill-1-of-3 availability {} < 0.99 (served {served}, failed {failed})",
        m.availability()
    );
    assert!(
        m.replicas[1].failures > 0,
        "the killed replica must have recorded failures"
    );
    assert!(m.retries > 0, "failover must have re-queued tries");
}

#[test]
fn routing_never_picks_dead_replica() {
    // Regression for the power-of-two-choices tie-break: its paired
    // Relaxed `inflight` loads are deliberately racy (see the pragma in
    // `pick_replica`), and this pins the property that makes the race
    // benign — health gating, not the load comparison, decides which
    // replicas are routable at all.  Once a replica is Dead it must
    // receive zero further request tries (probes are counted separately
    // and keep flowing — they are the path back to life).
    let cluster = ClusterEngine::build_with(
        mnist(),
        ClusterConfig {
            replicas: 3,
            serve: fast_serve(),
            retry: fast_retry(),
            health: HealthPolicy {
                degraded_after: 1,
                dead_after: 2,
                probe_interval: Duration::from_millis(5),
                probe_timeout: Duration::from_millis(20),
                ..HealthPolicy::default()
            },
            ..ClusterConfig::default()
        },
        null_factory(),
    )
    .unwrap();
    // Replica 2 is dark before any traffic arrives.
    cluster.fault(2).kill();
    // Phase 1: drive traffic until health demotes it to Dead (the first
    // few tries may legitimately land there while it still looks alive).
    let deadline = Instant::now() + WATCHDOG;
    while cluster.health()[2] != Health::Dead {
        assert!(Instant::now() < deadline, "replica 2 never went Dead");
        let t = cluster.submit("mnist", vec![0.5; 784]).unwrap();
        let _ = t.wait_timeout(WATCHDOG).unwrap();
    }
    // Every phase-1 ticket is resolved, so no request try is still in
    // flight; give any metrics straggler a beat, then snapshot.
    std::thread::sleep(Duration::from_millis(10));
    let tries_when_dead = cluster.metrics().replicas[2].tries;
    // Phase 2: with the replica Dead, routing must never pick it again.
    let mut tickets = Vec::with_capacity(80);
    for _ in 0..80 {
        tickets.push(cluster.submit("mnist", vec![0.5; 784]).unwrap());
    }
    for t in &tickets {
        let c = t
            .wait_timeout(WATCHDOG)
            .unwrap()
            .unwrap_or_else(|| panic!("hung ticket {} — watchdog fired", t.id()));
        assert!(c.served(), "healthy majority must serve while r2 is dead");
    }
    assert_eq!(
        cluster.health()[2],
        Health::Dead,
        "kill is permanent — r2 must stay Dead under load"
    );
    cluster.shutdown();
    let m = cluster.metrics();
    assert_eq!(
        m.replicas[2].tries, tries_when_dead,
        "routing picked a Dead replica: {} request tries landed on r2 after death",
        m.replicas[2].tries - tries_when_dead
    );
    assert!(
        m.replicas[2].probes > 0,
        "probes must keep flowing to a Dead replica (they are the revival path)"
    );
}

#[test]
fn energy_is_charged_only_for_executed_work() {
    // replica 0 is dark from t=0 (permanent chaos kill) and probes are
    // effectively disabled, so any energy on r0 could only come from a
    // charging bug: batches that *fail* must charge nothing.
    let cluster = ClusterEngine::build_with(
        mnist(),
        ClusterConfig {
            replicas: 3,
            serve: fast_serve(),
            retry: fast_retry(),
            health: HealthPolicy {
                probe_interval: Duration::from_secs(3600),
                ..HealthPolicy::default()
            },
            chaos: ChaosSpec::parse("kill@0ms:r0").unwrap(),
            ..ClusterConfig::default()
        },
        null_factory(),
    )
    .unwrap();
    // let the supervisor apply the t=0 kill before traffic arrives
    std::thread::sleep(Duration::from_millis(20));
    let n = 30usize;
    let tickets: Vec<_> = (0..n)
        .map(|_| cluster.submit("mnist", vec![0.25; 784]).unwrap())
        .collect();
    for t in &tickets {
        let c = t
            .wait_timeout(WATCHDOG)
            .unwrap()
            .expect("ticket must resolve");
        assert!(c.served(), "two live replicas must absorb all traffic");
    }
    cluster.shutdown();
    let m = cluster.metrics();
    assert_eq!(m.completed, n as u64);
    assert_eq!(
        m.replicas[0].serve.photonic_energy_j, 0.0,
        "killed replica charged energy for work it never executed"
    );
    assert_eq!(m.replicas[0].serve.completed, 0);
    let live_energy: f64 = m.replicas[1..]
        .iter()
        .map(|r| r.serve.photonic_energy_j)
        .sum();
    assert!(live_energy > 0.0);
    assert!(
        (m.serve.photonic_energy_j - live_energy).abs() <= 1e-12 * live_energy,
        "rollup must equal the live replicas' executed work"
    );
}

#[test]
fn all_replicas_dead_resolves_replica_failed_within_budget() {
    let cluster = ClusterEngine::build_with(
        mnist(),
        ClusterConfig {
            replicas: 2,
            serve: fast_serve(),
            retry: RetryPolicy {
                max_tries: 3,
                per_try_timeout: Duration::from_millis(25),
                base_backoff: Duration::from_micros(500),
                max_backoff: Duration::from_millis(2),
                ..RetryPolicy::default()
            },
            ..ClusterConfig::default()
        },
        null_factory(),
    )
    .unwrap();
    cluster.fault(0).kill();
    cluster.fault(1).kill();
    let tickets: Vec<_> = (0..5)
        .map(|_| cluster.submit("mnist", vec![0.25; 784]).unwrap())
        .collect();
    for t in &tickets {
        let c = t
            .wait_timeout(WATCHDOG)
            .unwrap()
            .expect("retry-budget exhaustion must resolve the ticket, not hang it");
        assert_eq!(c.outcome, Outcome::ReplicaFailed);
        assert!(!c.served());
    }
    cluster.shutdown();
    let m = cluster.metrics();
    assert_eq!(m.replica_failed, 5);
    assert_eq!(m.completed, 0);
    assert!((m.availability() - 0.0).abs() < 1e-12);
    // budget respected: at most max_tries engine submits per request
    assert!(
        m.tries <= 5 * 3,
        "tries {} exceeded the per-request budget",
        m.tries
    );
}

#[test]
fn dead_replica_rewarms_through_degraded_after_revival() {
    let cluster = ClusterEngine::build_with(
        mnist(),
        ClusterConfig {
            replicas: 2,
            serve: fast_serve(),
            retry: fast_retry(),
            health: HealthPolicy {
                degraded_after: 2,
                dead_after: 4,
                probe_interval: Duration::from_millis(5),
                probe_timeout: Duration::from_millis(100),
                rewarm_successes: 2,
                ..HealthPolicy::default()
            },
            ..ClusterConfig::default()
        },
        null_factory(),
    )
    .unwrap();
    cluster.fault(0).kill();
    // drive traffic until the failing replica is demoted to Dead
    let t0 = Instant::now();
    while cluster.health()[0] != Health::Dead {
        assert!(
            t0.elapsed() < WATCHDOG,
            "replica 0 never went Dead (health {:?})",
            cluster.health()
        );
        let t = cluster.submit("mnist", vec![0.25; 784]).unwrap();
        t.wait_timeout(WATCHDOG).unwrap().expect("resolve");
    }
    // revive: the probe trickle must walk it Dead -> Degraded -> Healthy
    cluster.fault(0).revive();
    let t0 = Instant::now();
    while cluster.health()[0] != Health::Healthy {
        assert!(
            t0.elapsed() < WATCHDOG,
            "replica 0 never re-warmed (health {:?})",
            cluster.health()
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    cluster.shutdown();
    let m = cluster.metrics();
    assert!(m.replicas[0].probes > 0, "recovery must come from probes");
    assert!(
        m.replicas[0].time_dead > Duration::ZERO,
        "the Dead interval must be accounted"
    );
}

// ---- satellite: Ticket::wait_timeout under failover -------------------------

#[test]
fn wait_timeout_times_out_then_still_resolves() {
    // single stalled replica: wait_timeout must return Ok(None) at the
    // deadline without consuming the ticket, and a later wait still
    // gets the completion once the stall clears.
    let cluster = ClusterEngine::build_with(
        mnist(),
        ClusterConfig {
            replicas: 1,
            serve: fast_serve(),
            retry: RetryPolicy {
                // long enough that the stalled try is waited out, not
                // abandoned — this test is about the ticket API
                per_try_timeout: Duration::from_secs(5),
                ..RetryPolicy::default()
            },
            ..ClusterConfig::default()
        },
        null_factory(),
    )
    .unwrap();
    cluster.fault(0).stall_for(Duration::from_millis(150));
    let t = cluster.submit("mnist", vec![0.25; 784]).unwrap();
    let early = t.wait_timeout(Duration::from_millis(20)).unwrap();
    assert!(early.is_none(), "stalled request resolved impossibly early");
    let c = t
        .wait_timeout(WATCHDOG)
        .unwrap()
        .expect("request must complete after the stall clears");
    assert!(c.served());
    assert!(c.wall_latency >= Duration::from_millis(100));
    cluster.shutdown();
}

#[test]
fn wait_timeout_under_failover_resolves_in_bounded_time() {
    // replica 1 stalls long; per-try timeout abandons the stuck tries
    // and fails them over, so every wait_timeout resolves well before
    // the stall would have ended.
    let stall = Duration::from_secs(3);
    let cluster = ClusterEngine::build_with(
        mnist(),
        ClusterConfig {
            replicas: 3,
            serve: fast_serve(),
            retry: RetryPolicy {
                per_try_timeout: Duration::from_millis(20),
                base_backoff: Duration::from_micros(500),
                max_backoff: Duration::from_millis(2),
                ..RetryPolicy::default()
            },
            ..ClusterConfig::default()
        },
        slow_factory(Duration::from_micros(200)),
    )
    .unwrap();
    let n = 60usize;
    let mut tickets = Vec::with_capacity(n);
    let t0 = Instant::now();
    for i in 0..n {
        if i == n / 4 {
            cluster.fault(1).stall_for(stall);
        }
        tickets.push(cluster.submit("mnist", vec![0.25; 784]).unwrap());
        std::thread::sleep(Duration::from_micros(200));
    }
    for t in &tickets {
        let c = t
            .wait_timeout(Duration::from_secs(2))
            .unwrap()
            .expect("failover must resolve every ticket long before the stall ends");
        assert!(c.served(), "ticket {} not served: {:?}", t.id(), c.outcome);
    }
    assert!(
        t0.elapsed() < stall,
        "the whole run must finish before the stalled replica wakes"
    );
    cluster.shutdown();
    let m = cluster.metrics();
    assert!(m.retries > 0, "stalled tries must have been re-queued");
    assert_eq!(m.completed, n as u64);
}
