//! Integration tests for the network serving edge: wire round trips over
//! real sockets, tenant admission (401/429), QoS header plumbing into the
//! engine's lanes (clamping, deadlines → 504), graceful drain, and the
//! load generator.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use sonic::util::sync::LockExt;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use sonic::model::ModelDesc;
use sonic::serve::net::protocol::{
    parse_frame, parse_http_response, write_frame, Parsed, FRAME_MAGIC,
};
use sonic::serve::net::{LoadGen, NetConfig, NetServer, TenantLoad, TenantSpec};
use sonic::serve::workload::Arrivals;
use sonic::serve::{
    BackendChoice, Engine, InferenceBackend, NullBackend, Priority, ServeConfig,
};
use sonic::util::err::Result;
use sonic::util::json::Json;

fn null_backend(input_len: usize) -> Arc<NullBackend> {
    Arc::new(NullBackend {
        input_len,
        n_classes: 10,
    })
}

/// Backend whose batches block while the test holds `gate` — makes
/// in-flight states deterministic.
struct GatedBackend {
    gate: Arc<Mutex<()>>,
    inner: NullBackend,
}

impl InferenceBackend for GatedBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let _g = self.gate.lock_or_recover();
        self.inner.infer_batch(inputs)
    }
    fn input_len(&self) -> usize {
        self.inner.input_len()
    }
}

fn mnist_engine(backend: Arc<dyn InferenceBackend>) -> Arc<Engine> {
    Arc::new(
        Engine::builder()
            .serve_config(ServeConfig {
                max_batch: 4,
                batch_window: Duration::from_millis(1),
                queue_cap: 64,
                ..ServeConfig::default()
            })
            .model_desc(
                ModelDesc::builtin("mnist").unwrap(),
                BackendChoice::Custom(backend),
            )
            .build()
            .unwrap(),
    )
}

fn spec(name: &str, key: &str, rate: f64, burst: f64, prio: Priority) -> TenantSpec {
    TenantSpec {
        name: name.into(),
        api_key: key.into(),
        rate_rps: rate,
        burst,
        max_priority: prio,
        weight: 1,
    }
}

/// An unlimited High-ceiling tenant ("t"/"k") — the default for tests
/// that aren't about admission.
fn open_specs() -> Vec<TenantSpec> {
    vec![spec("t", "k", 0.0, 0.0, Priority::High)]
}

fn connect(server: &NetServer) -> TcpStream {
    let s = TcpStream::connect(server.connect_addr()).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s
}

/// A one-hot POST body: NullBackend maps one-hot at `j` to argmax `j % 10`.
fn infer_request(key: &str, hot: usize, extra_headers: &str) -> Vec<u8> {
    let mut vals = vec!["0"; 784];
    vals[hot] = "1";
    let body = format!("[{}]", vals.join(","));
    format!(
        "POST /v1/models/mnist/infer HTTP/1.1\r\nx-api-key: {key}\r\n{extra_headers}content-length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Read one HTTP response off the stream: `(status, body JSON)`.
fn recv_http(stream: &mut TcpStream, buf: &mut Vec<u8>) -> (u16, Json) {
    loop {
        match parse_http_response(buf) {
            Parsed::Complete((status, body), used) => {
                buf.drain(..used);
                let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
                return (status, json);
            }
            Parsed::Malformed(why) => panic!("malformed response: {why}"),
            Parsed::Incomplete => {}
        }
        let mut tmp = [0u8; 4096];
        let n = stream.read(&mut tmp).expect("read");
        assert!(n > 0, "connection closed mid-response");
        buf.extend_from_slice(&tmp[..n]);
    }
}

/// Read one framed response off the stream: `(header JSON, floats)`.
fn recv_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> (Json, Vec<f32>) {
    loop {
        match parse_frame(buf) {
            Parsed::Complete(frame, used) => {
                buf.drain(..used);
                return (frame.header, frame.floats);
            }
            Parsed::Malformed(why) => panic!("malformed frame: {why}"),
            Parsed::Incomplete => {}
        }
        let mut tmp = [0u8; 4096];
        let n = stream.read(&mut tmp).expect("read");
        assert!(n > 0, "connection closed mid-frame");
        buf.extend_from_slice(&tmp[..n]);
    }
}

#[test]
fn http_round_trip_keeps_the_connection_alive() {
    let engine = mnist_engine(null_backend(784));
    let server = NetServer::bind(
        "127.0.0.1:0",
        Arc::clone(&engine),
        open_specs(),
        NetConfig::default(),
    )
    .unwrap();
    let mut conn = connect(&server);
    let mut buf = Vec::new();
    // two sequential inferences on ONE connection, then a health check
    for hot in [3usize, 7] {
        conn.write_all(&infer_request("k", hot, "")).unwrap();
        let (status, json) = recv_http(&mut conn, &mut buf);
        assert_eq!(status, 200, "{json:?}");
        assert_eq!(json.get("argmax").unwrap().as_f64(), Some(hot as f64));
        assert_eq!(json.get("outcome").unwrap().as_str(), Some("served"));
        assert_eq!(json.get("logits").unwrap().as_arr().unwrap().len(), 10);
    }
    conn.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
    let (status, json) = recv_http(&mut conn, &mut buf);
    assert_eq!(status, 200);
    assert_eq!(json.get("status").unwrap().as_str(), Some("ok"));
    // model listing names mnist with its input length
    conn.write_all(b"GET /v1/models HTTP/1.1\r\n\r\n").unwrap();
    let (status, json) = recv_http(&mut conn, &mut buf);
    assert_eq!(status, 200);
    let models = json.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models[0].get("name").unwrap().as_str(), Some("mnist"));
    assert_eq!(models[0].get("input_len").unwrap().as_f64(), Some(784.0));
    server.shutdown();
    engine.shutdown();
}

#[test]
fn framed_round_trip_echoes_id_and_raw_logits() {
    let engine = mnist_engine(null_backend(784));
    let server = NetServer::bind(
        "127.0.0.1:0",
        Arc::clone(&engine),
        open_specs(),
        NetConfig::default(),
    )
    .unwrap();
    let mut conn = connect(&server);
    conn.write_all(&FRAME_MAGIC).unwrap();
    let mut input = vec![0.0f32; 784];
    input[5] = 1.0;
    let header = sonic::util::json::obj(vec![
        ("model", sonic::util::json::s("mnist")),
        ("api_key", sonic::util::json::s("k")),
        ("priority", sonic::util::json::s("high")),
        ("id", sonic::util::json::num(42.0)),
    ]);
    let mut wire = Vec::new();
    write_frame(&mut wire, &header, &input);
    conn.write_all(&wire).unwrap();
    let mut buf = Vec::new();
    let (resp, logits) = recv_frame(&mut conn, &mut buf);
    assert_eq!(resp.get("status").unwrap().as_f64(), Some(200.0));
    assert_eq!(resp.get("id").unwrap().as_f64(), Some(42.0));
    assert_eq!(resp.get("argmax").unwrap().as_f64(), Some(5.0));
    assert_eq!(logits.len(), 10);
    assert!((logits[5] - 1.0).abs() < 1e-6);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn auth_and_routing_errors_map_to_statuses() {
    let engine = mnist_engine(null_backend(784));
    let server = NetServer::bind(
        "127.0.0.1:0",
        Arc::clone(&engine),
        open_specs(),
        NetConfig::default(),
    )
    .unwrap();
    let mut conn = connect(&server);
    let mut buf = Vec::new();
    let cases: Vec<(Vec<u8>, u16)> = vec![
        // no API key
        (infer_request("", 0, ""), 401),
        // unknown API key
        (infer_request("wrong", 0, ""), 401),
        // unknown model
        (
            b"POST /v1/models/nope/infer HTTP/1.1\r\nx-api-key: k\r\ncontent-length: 5\r\n\r\n[1,2]".to_vec(),
            404,
        ),
        // wrong input length
        (
            b"POST /v1/models/mnist/infer HTTP/1.1\r\nx-api-key: k\r\ncontent-length: 5\r\n\r\n[1,2]".to_vec(),
            400,
        ),
        // bad body
        (
            b"POST /v1/models/mnist/infer HTTP/1.1\r\nx-api-key: k\r\ncontent-length: 4\r\n\r\nwhat".to_vec(),
            400,
        ),
        // bad priority header
        (infer_request("k", 0, "x-priority: urgent\r\n"), 400),
        // unknown paths and methods
        (b"GET /nope HTTP/1.1\r\n\r\n".to_vec(), 404),
        (b"POST /nope HTTP/1.1\r\ncontent-length: 0\r\n\r\n".to_vec(), 404),
        (b"DELETE /healthz HTTP/1.1\r\n\r\n".to_vec(), 405),
    ];
    for (req, want) in cases {
        conn.write_all(&req).unwrap();
        let (status, json) = recv_http(&mut conn, &mut buf);
        assert_eq!(status, want, "request {:?} -> {json:?}", String::from_utf8_lossy(&req));
        assert!(json.get("error").is_some());
    }
    server.shutdown();
    engine.shutdown();
}

#[test]
fn rate_limit_answers_429_and_counts_it() {
    let engine = mnist_engine(null_backend(784));
    // burst of 1, refill far slower than the test: second request MUST 429
    let server = NetServer::bind(
        "127.0.0.1:0",
        Arc::clone(&engine),
        vec![spec("rl", "rk", 0.001, 1.0, Priority::Normal)],
        NetConfig::default(),
    )
    .unwrap();
    let mut conn = connect(&server);
    let mut buf = Vec::new();
    conn.write_all(&infer_request("rk", 1, "")).unwrap();
    let (status, _) = recv_http(&mut conn, &mut buf);
    assert_eq!(status, 200);
    let mut seen_429: u64 = 0;
    for _ in 0..3 {
        conn.write_all(&infer_request("rk", 1, "")).unwrap();
        let (status, json) = recv_http(&mut conn, &mut buf);
        assert_eq!(status, 429, "{json:?}");
        assert_eq!(json.get("error").unwrap().as_str(), Some("rate limited"));
        seen_429 += 1;
    }
    // every refusal got a response AND a counter — never silently dropped
    let counters = server.tenant_counters();
    let (_, c) = counters.iter().find(|(n, _)| n == "rl").unwrap();
    assert_eq!(c.rate_limited, seen_429);
    assert_eq!(c.submitted, 1 + seen_429);
    assert_eq!(c.served, 1);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn priority_header_reaches_the_lanes_and_clamps_to_tenant_ceiling() {
    let engine = mnist_engine(null_backend(784));
    let server = NetServer::bind(
        "127.0.0.1:0",
        Arc::clone(&engine),
        vec![
            spec("vip", "vip-key", 0.0, 0.0, Priority::High),
            spec("std", "std-key", 0.0, 0.0, Priority::Normal),
        ],
        NetConfig::default(),
    )
    .unwrap();
    let mut conn = connect(&server);
    let mut buf = Vec::new();
    // vip asks High and gets it; std asks High and is clamped to Normal
    conn.write_all(&infer_request("vip-key", 0, "x-priority: high\r\n"))
        .unwrap();
    let (status, json) = recv_http(&mut conn, &mut buf);
    assert_eq!(status, 200);
    assert_eq!(json.get("lane").unwrap().as_str(), Some("high"));
    conn.write_all(&infer_request("std-key", 0, "x-priority: high\r\n"))
        .unwrap();
    let (status, json) = recv_http(&mut conn, &mut buf);
    assert_eq!(status, 200);
    assert_eq!(json.get("lane").unwrap().as_str(), Some("normal"));
    server.shutdown();
    engine.shutdown();
    // the engine's own lane counters saw exactly one request per lane
    let metrics = engine.metrics();
    let m = metrics.model("mnist").unwrap();
    let completed = |p: Priority| {
        m.lanes
            .iter()
            .find(|l| l.priority == p)
            .map_or(0, |l| l.completed)
    };
    assert_eq!(completed(Priority::High), 1);
    assert_eq!(completed(Priority::Normal), 1);
    assert_eq!(completed(Priority::Batch), 0);
}

#[test]
fn deadline_header_sheds_queued_requests_as_504() {
    let gate = Arc::new(Mutex::new(()));
    let engine = mnist_engine(Arc::new(GatedBackend {
        gate: Arc::clone(&gate),
        inner: NullBackend {
            input_len: 784,
            n_classes: 10,
        },
    }));
    let server = NetServer::bind(
        "127.0.0.1:0",
        Arc::clone(&engine),
        open_specs(),
        NetConfig::default(),
    )
    .unwrap();
    // hold the gate: request A occupies the backend, request B (1 ms
    // deadline) expires in the queue behind it
    let held = gate.lock_or_recover();
    let mut conn_a = connect(&server);
    let mut conn_b = connect(&server);
    conn_a.write_all(&infer_request("k", 0, "")).unwrap();
    std::thread::sleep(Duration::from_millis(60)); // A reaches the backend
    conn_b
        .write_all(&infer_request("k", 1, "x-deadline-ms: 1\r\n"))
        .unwrap();
    std::thread::sleep(Duration::from_millis(60)); // B's deadline expires
    drop(held);
    let mut buf = Vec::new();
    let (status_a, _) = recv_http(&mut conn_a, &mut buf);
    assert_eq!(status_a, 200);
    let mut buf_b = Vec::new();
    let (status_b, json_b) = recv_http(&mut conn_b, &mut buf_b);
    assert_eq!(status_b, 504, "{json_b:?}");
    assert_eq!(
        json_b.get("outcome").unwrap().as_str(),
        Some("deadline_exceeded")
    );
    server.shutdown();
    engine.shutdown();
    // shed is visible in BOTH the tenant counters and the engine lanes
    let counters = server.tenant_counters();
    let (_, c) = counters.iter().find(|(n, _)| n == "t").unwrap();
    assert_eq!(c.deadline_shed, 1);
    let metrics = engine.metrics();
    assert_eq!(metrics.model("mnist").unwrap().serve.shed, 1);
}

/// Satellite 3: graceful drain — every in-flight request is answered,
/// new connections are refused afterwards.  Watchdogged: a hang here is
/// a bug, not a slow machine.
#[test]
fn graceful_drain_answers_inflight_and_refuses_new_connections() {
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let gate = Arc::new(Mutex::new(()));
        let engine = mnist_engine(Arc::new(GatedBackend {
            gate: Arc::clone(&gate),
            inner: NullBackend {
                input_len: 784,
                n_classes: 10,
            },
        }));
        let server = NetServer::bind(
            "127.0.0.1:0",
            Arc::clone(&engine),
            open_specs(),
            NetConfig::default(),
        )
        .unwrap();
        let addr = server.connect_addr();
        // three connections, each with one request in flight behind the
        // held gate
        let held = gate.lock_or_recover();
        let mut conns: Vec<TcpStream> = (0..3).map(|_| connect(&server)).collect();
        for (i, c) in conns.iter_mut().enumerate() {
            c.write_all(&infer_request("k", i, "")).unwrap();
        }
        std::thread::sleep(Duration::from_millis(100)); // all admitted
        // drain in the background (it must wait for the gate), then let
        // the backend finish
        let drainer = std::thread::spawn(move || server.shutdown());
        std::thread::sleep(Duration::from_millis(100));
        drop(held);
        // EVERY in-flight request gets its real answer
        for (i, c) in conns.iter_mut().enumerate() {
            let mut buf = Vec::new();
            let (status, json) = recv_http(c, &mut buf);
            assert_eq!(status, 200, "conn {i}: {json:?}");
            assert_eq!(json.get("argmax").unwrap().as_f64(), Some(i as f64));
        }
        assert!(drainer.join().unwrap(), "drain timed out");
        // new connections are refused (or immediately closed) after drain
        match TcpStream::connect_timeout(&addr, Duration::from_secs(1)) {
            Err(_) => {}
            Ok(mut s) => {
                s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
                let mut tmp = [0u8; 16];
                match s.read(&mut tmp) {
                    Ok(0) => {}                    // EOF: closed by the server
                    Err(_) => {}                   // reset: also refused
                    Ok(n) => panic!("drained server answered with {n} bytes"),
                }
            }
        }
        engine.shutdown();
        done_tx.send(()).unwrap();
    });
    done_rx
        .recv_timeout(Duration::from_secs(20))
        .expect("graceful-drain test wedged");
}

/// The remote drain endpoint: admin-tier gated, flips the gateway into
/// draining (in-flight requests finish, new work gets 503), and hands
/// the blocking shutdown to the server's owner via `drain_requested()`.
/// Watchdogged: a hang here is a bug, not a slow machine.
#[test]
fn admin_drain_endpoint_is_gated_and_drains_gracefully() {
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let gate = Arc::new(Mutex::new(()));
        let engine = mnist_engine(Arc::new(GatedBackend {
            gate: Arc::clone(&gate),
            inner: NullBackend {
                input_len: 784,
                n_classes: 10,
            },
        }));
        // a long poll_interval keeps idle handlers blocked in read while
        // the test races the drain flag against a late request
        let server = NetServer::bind(
            "127.0.0.1:0",
            Arc::clone(&engine),
            vec![
                spec("gold", "gold-key", 0.0, 0.0, Priority::High),
                spec("free", "free-key", 0.0, 0.0, Priority::Batch),
            ],
            NetConfig {
                poll_interval: Duration::from_millis(100),
                ..NetConfig::default()
            },
        )
        .unwrap();
        let drain_req = |key: &str| {
            let auth = if key.is_empty() {
                String::new()
            } else {
                format!("x-api-key: {key}\r\n")
            };
            format!("POST /v1/admin/drain HTTP/1.1\r\n{auth}content-length: 0\r\n\r\n").into_bytes()
        };
        // one request in flight behind the held gate — it must survive
        // the drain and get its real answer
        let held = gate.lock_or_recover();
        let mut conn_inflight = connect(&server);
        conn_inflight.write_all(&infer_request("gold-key", 4, "")).unwrap();
        // a second idle connection, opened pre-drain, to prove new work
        // is refused with 503 once draining
        let mut conn_late = connect(&server);
        std::thread::sleep(Duration::from_millis(50));

        let mut conn_admin = connect(&server);
        let mut buf = Vec::new();
        // no key -> 401; non-admin tier -> 403; neither starts the drain
        conn_admin.write_all(&drain_req("")).unwrap();
        let (status, json) = recv_http(&mut conn_admin, &mut buf);
        assert_eq!(status, 401, "{json:?}");
        conn_admin.write_all(&drain_req("free-key")).unwrap();
        let (status, json) = recv_http(&mut conn_admin, &mut buf);
        assert_eq!(status, 403, "{json:?}");
        assert!(!server.drain_requested(), "rejected drains must not drain");

        // admin tier -> 200 and the flag flips for the owner to act on
        conn_admin.write_all(&drain_req("gold-key")).unwrap();
        let (status, json) = recv_http(&mut conn_admin, &mut buf);
        assert_eq!(status, 200, "{json:?}");
        assert_eq!(json.get("status").unwrap().as_str(), Some("draining"));
        assert!(server.drain_requested());

        // new work is refused immediately, even with a valid key
        conn_late.write_all(&infer_request("gold-key", 0, "")).unwrap();
        let mut buf_late = Vec::new();
        let (status, json) = recv_http(&mut conn_late, &mut buf_late);
        assert_eq!(status, 503, "{json:?}");
        assert_eq!(json.get("error").unwrap().as_str(), Some("draining"));

        // the in-flight request still completes with its real answer
        drop(held);
        let mut buf_inflight = Vec::new();
        let (status, json) = recv_http(&mut conn_inflight, &mut buf_inflight);
        assert_eq!(status, 200, "{json:?}");
        assert_eq!(json.get("argmax").unwrap().as_f64(), Some(4.0));

        // the owner completes the blocking drain; afterwards new
        // connections are refused (or immediately closed)
        let addr = server.connect_addr();
        assert!(server.shutdown(), "drain timed out");
        match TcpStream::connect_timeout(&addr, Duration::from_secs(1)) {
            Err(_) => {}
            Ok(mut s) => {
                s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
                let mut tmp = [0u8; 16];
                match s.read(&mut tmp) {
                    Ok(0) => {}
                    Err(_) => {}
                    Ok(n) => panic!("drained server answered with {n} bytes"),
                }
            }
        }
        engine.shutdown();
        done_tx.send(()).unwrap();
    });
    done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("admin-drain test wedged");
}

/// A slow backend makes the loopback gateway genuinely overloaded, so the
/// loadgen smoke sees both 2xx and 429 deterministically.
struct SlowBackend {
    inner: NullBackend,
    delay: Duration,
}

impl InferenceBackend for SlowBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        self.inner.infer_batch(inputs)
    }
    fn input_len(&self) -> usize {
        self.inner.input_len()
    }
}

#[test]
fn loadgen_drives_tenants_and_reports_throttling() {
    let engine = mnist_engine(Arc::new(SlowBackend {
        inner: NullBackend {
            input_len: 784,
            n_classes: 10,
        },
        delay: Duration::from_micros(500),
    }));
    let server = NetServer::bind(
        "127.0.0.1:0",
        Arc::clone(&engine),
        vec![
            spec("gold", "gold-key", 0.0, 0.0, Priority::High),
            spec("free", "free-key", 0.5, 2.0, Priority::Batch),
        ],
        NetConfig::default(),
    )
    .unwrap();
    let load = |label: &str, key: &str, n, framed, prio| TenantLoad {
        label: label.into(),
        api_key: key.into(),
        model: "mnist".into(),
        input_len: 784,
        requests: n,
        connections: 2,
        arrivals: Arrivals::poisson(500.0),
        priority: prio,
        deadline_ms: None,
        framed,
        seed: 11,
    };
    let gen = LoadGen {
        target: server.connect_addr(),
        tenants: vec![
            load("gold", "gold-key", 24, true, Priority::High),
            load("free", "free-key", 16, false, Priority::Batch),
        ],
    };
    let report = gen.run();
    let gold = report.tenant("gold").unwrap();
    let free = report.tenant("free").unwrap();
    assert_eq!(gold.sent, 24);
    assert_eq!(gold.ok_2xx, 24, "unlimited tenant fully served");
    assert_eq!(gold.transport_errors, 0);
    assert!(free.ok_2xx >= 1, "free burst admits a couple");
    assert!(free.http_429 >= 1, "tight bucket must throttle: {free:?}");
    assert_eq!(
        free.sent,
        free.ok_2xx + free.http_429 + free.http_503 + free.http_504 + free.other_status,
        "every request got exactly one response"
    );
    // the report serializes with per-tenant percentiles
    let json = report.to_json();
    let t = json.get("tenants").unwrap();
    assert!(t.get("gold").unwrap().get("p99_us").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(
        t.get("free").unwrap().get("http_429").unwrap().as_f64(),
        Some(free.http_429 as f64)
    );
    server.shutdown();
    engine.shutdown();
}
