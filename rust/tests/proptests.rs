//! Property-based tests on coordinator invariants (routing, batching,
//! compression, scheduling) using the in-tree property harness
//! (`sonic::util::prop`, the offline proptest substitute).

use sonic::arch::SonicConfig;
use sonic::coordinator::compress::{compress_fc, fc_product};
use sonic::coordinator::convflow::{compressed_dot, extract_patch, CompressedKernel};
use sonic::coordinator::schedule::{schedule_conv, schedule_fc};
use sonic::sparsity::{ColMatrix, SparseVec};
use sonic::tensor::swt::{parse_swt, write_swt};
use sonic::tensor::Tensor;
use sonic::util::prop::{check, Config, Gen};

fn dense_matvec(rows: usize, cols: usize, w_rm: &[f32], a: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; rows];
    for r in 0..rows {
        for c in 0..cols {
            y[r] += w_rm[r * cols + c] * a[c];
        }
    }
    y
}

#[test]
fn prop_fc_compression_lossless() {
    check("fc compression lossless", Config::default(), |g: &mut Gen| {
        let rows = g.dim(1, 24);
        let cols = g.dim(1, 48);
        let sparsity = g.f64(0.0, 0.95);
        let wsp = g.f64(0.0, 0.9);
        let w_rm = g.sparse_vec(rows * cols, wsp);
        let a = g.sparse_vec(cols, sparsity);
        let w = ColMatrix::from_row_major(rows, cols, &w_rm);
        let c = compress_fc(&a, &w);
        let got = fc_product(&c);
        let want = dense_matvec(rows, cols, &w_rm, &a);
        for (i, (x, y)) in got.iter().zip(&want).enumerate() {
            if (x - y).abs() > 1e-3 {
                return Err(format!("row {i}: {x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fc_compression_never_grows() {
    check("compressed dim <= original", Config::default(), |g| {
        let cols = g.dim(1, 100);
        let asp = g.f64(0.0, 1.0);
        let a = g.sparse_vec(cols, asp);
        let w = ColMatrix::from_row_major(1, cols, &g.sparse_vec(cols, 0.0));
        let c = compress_fc(&a, &w);
        if c.activations.len() > cols {
            return Err(format!("{} > {cols}", c.activations.len()));
        }
        if c.activations.iter().any(|&x| x == 0.0) {
            return Err("compressed vector contains zeros".into());
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_fc_invariants() {
    check("fc schedule invariants", Config::default(), |g| {
        let rows = g.dim(1, 30);
        let cols = g.dim(1, 80);
        let wsp = g.f64(0.0, 0.9);
        let w_rm = g.sparse_vec(rows * cols, wsp);
        let asp = g.f64(0.0, 0.9);
        let a = g.sparse_vec(cols, asp);
        let w = ColMatrix::from_row_major(rows, cols, &w_rm);
        let c = compress_fc(&a, &w);
        let cfg = SonicConfig::paper_best();
        let s = schedule_fc(&c, &cfg);

        // every pass respects lane bounds and VDU id range
        for p in &s.passes {
            if p.lanes_used as usize > cfg.m {
                return Err(format!("lanes_used {} > m", p.lanes_used));
            }
            if p.lanes_active > p.lanes_used {
                return Err("active > used".into());
            }
            if p.vdu as usize >= cfg.n_fc_vdus {
                return Err(format!("vdu {} out of range", p.vdu));
            }
        }
        // round-robin balance: per-VDU pass counts differ by <= 1
        let mut per = vec![0i64; cfg.n_fc_vdus];
        for p in &s.passes {
            per[p.vdu as usize] += 1;
        }
        let (mn, mx) = (per.iter().min().unwrap(), per.iter().max().unwrap());
        if mx - mn > 1 {
            return Err(format!("imbalance {per:?}"));
        }
        // pass count formula
        let kept = a.iter().filter(|&&x| x != 0.0).count();
        let expect = if kept == 0 {
            0
        } else {
            rows * kept.div_ceil(cfg.m)
        };
        if s.passes.len() != expect {
            return Err(format!("passes {} != {expect}", s.passes.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_gating_monotone() {
    // enabling power gating never increases active lanes
    check("gating monotone", Config::default(), |g| {
        let rows = g.dim(1, 10);
        let cols = g.dim(1, 60);
        let wsp = g.f64(0.2, 0.9);
        let w_rm = g.sparse_vec(rows * cols, wsp);
        let a = g.sparse_vec(cols, 0.3);
        let w = ColMatrix::from_row_major(rows, cols, &w_rm);
        let c = compress_fc(&a, &w);
        let on = schedule_fc(&c, &SonicConfig::paper_best());
        let off = schedule_fc(&c, &SonicConfig::paper_best().without_power_gating());
        if on.passes.len() != off.passes.len() {
            return Err("pass count changed by gating".into());
        }
        for (p_on, p_off) in on.passes.iter().zip(&off.passes) {
            if p_on.lanes_active > p_off.lanes_active {
                return Err("gating increased activity".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_conv_kernel_compression_roundtrip() {
    check("conv kernel compression", Config::default(), |g| {
        let len = g.dim(1, 120);
        let ksp = g.f64(0.0, 0.95);
        let kflat = g.sparse_vec(len, ksp);
        let k = CompressedKernel::from_dense(&kflat);
        // dot against arbitrary patch == dense dot
        let psp = g.f64(0.0, 0.5);
        let patch = g.sparse_vec(len, psp);
        let want: f32 = kflat.iter().zip(&patch).map(|(a, b)| a * b).sum();
        let got = compressed_dot(&k, &patch);
        if (want - got).abs() > 1e-3 {
            return Err(format!("{got} vs {want}"));
        }
        // nnz preserved
        let nnz = kflat.iter().filter(|&&x| x != 0.0).count();
        if k.values.len() != nnz {
            return Err("nnz mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_conv_schedule_pass_formula() {
    check("conv schedule pass formula", Config::default(), |g| {
        let cfg = SonicConfig::paper_best();
        let kvol = g.dim(1, 60);
        let cout = g.dim(1, 6);
        let n_px = g.dim(1, 10);
        let kernels: Vec<CompressedKernel> = (0..cout)
            .map(|_| {
                let sp = g.f64(0.0, 0.9);
                CompressedKernel::from_dense(&g.sparse_vec(kvol, sp))
            })
            .collect();
        let patches: Vec<Vec<f32>> = (0..n_px).map(|_| g.sparse_vec(kvol, 0.2)).collect();
        let s = schedule_conv(&kernels, &patches, &cfg);
        let expect: usize = kernels
            .iter()
            .map(|k| k.values.len().div_ceil(cfg.n).max(1) * n_px)
            .sum();
        if s.passes.len() != expect {
            return Err(format!("{} != {expect}", s.passes.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_patch_extraction_bounds() {
    check("patch extraction in-bounds + padding", Config::default(), |g| {
        let h = g.dim(1, 12);
        let w = g.dim(1, 12);
        let c = g.dim(1, 4);
        let x = g.sparse_vec(h * w * c, 0.0);
        let oy = g.rng.range(0, h);
        let ox = g.rng.range(0, w);
        let p = extract_patch(&x, h, w, c, oy, ox, 3, 3);
        if p.len() != 9 * c {
            return Err(format!("patch len {}", p.len()));
        }
        // center element must equal the source pixel
        let center = &p[4 * c..5 * c];
        let src = &x[(oy * w + ox) * c..(oy * w + ox) * c + c];
        if center != src {
            return Err("center mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_vec_roundtrip() {
    check("sparse vec roundtrip", Config::default(), |g| {
        let n = g.dim(0, 200);
        let sp = g.f64(0.0, 1.0);
        let v = g.sparse_vec(n, sp);
        let s = SparseVec::from_dense(&v);
        if s.to_dense() != v {
            return Err("roundtrip failed".into());
        }
        let nnz = v.iter().filter(|&&x| x != 0.0).count();
        if s.nnz() != nnz {
            return Err("nnz mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_swt_pack_write_read_write_byte_identical() {
    // The export.py contract: any pack survives write -> read -> write with
    // byte-identical output.  Every case exercises a 0-dim (scalar) tensor
    // and an empty tensor (a zero-sized dim) alongside random-rank ones.
    check("swt byte-identical roundtrip", Config::default(), |g: &mut Gen| {
        let mut tensors = vec![
            Tensor::new("scalar", vec![], vec![g.rng.f32()]),
            Tensor::new("empty", vec![3, 0], vec![]),
            Tensor::new("empty0", vec![0], vec![]),
        ];
        let extra = g.dim(0, 5);
        for t in 0..extra {
            let rank = g.rng.range(0, 4);
            let mut dims = Vec::new();
            for _ in 0..rank {
                dims.push(if g.rng.bool(0.1) { 0 } else { g.dim(1, 6) });
            }
            let count: usize = dims.iter().product();
            tensors.push(Tensor::new(
                format!("t{t}.w"),
                dims,
                g.sparse_vec(count, 0.3),
            ));
        }
        let bytes1 = write_swt(&tensors);
        let back = match parse_swt(&bytes1) {
            Ok(b) => b,
            Err(e) => return Err(format!("parse failed: {e}")),
        };
        if back != tensors {
            return Err("tensors changed across roundtrip".into());
        }
        let bytes2 = write_swt(&back);
        if bytes2 != bytes1 {
            return Err(format!(
                "bytes differ: {} vs {}",
                bytes1.len(),
                bytes2.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_csc_kernel_matches_dense_reference() {
    // The acceptance property for the structurally-sparse kernels: the
    // compiled CSC path must equal the dense FcExec reference exactly
    // (same ascending-column accumulation order, so not just within a
    // tolerance) across weight sparsity 0.0..=0.99, batch 0/1/n, and
    // random activation sparsity.
    use sonic::plan::{FcExec, KernelChoice};
    check("csc kernel == dense kernel", Config::default(), |g: &mut Gen| {
        let rows = g.dim(1, 40);
        let cols = g.dim(1, 64);
        let wsp = g.f64(0.0, 0.99);
        let w = ColMatrix::from_row_major(rows, cols, &g.sparse_vec(rows * cols, wsp));
        let relu = g.rng.bool(0.5);
        let dense = FcExec::with_kernel(w.clone(), relu, 0.0, KernelChoice::Dense);
        let csc = FcExec::with_kernel(w, relu, 0.0, KernelChoice::Csc);
        for bn in [0usize, 1, g.dim(2, 9)] {
            let asp = g.f64(0.0, 1.0);
            let batch: Vec<Vec<f32>> = (0..bn).map(|_| g.sparse_vec(cols, asp)).collect();
            let yd = dense.forward_batch(&batch).map_err(|e| e.to_string())?;
            let yc = csc.forward_batch(&batch).map_err(|e| e.to_string())?;
            if yd != yc {
                return Err(format!(
                    "csc != dense (rows={rows} cols={cols} wsp={wsp:.3} batch={bn})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csc_kernel_survives_zero_columns_and_matrices() {
    // Degenerate structure: whole columns zeroed (empty CSC columns),
    // plus the all-zero matrix — the kernel must skip them without
    // touching the output.
    use sonic::plan::{FcExec, KernelChoice};
    check("csc kernel zero structure", Config::default(), |g: &mut Gen| {
        let rows = g.dim(1, 24);
        let cols = g.dim(1, 40);
        let mut w_rm = g.sparse_vec(rows * cols, 0.5);
        // zero a random subset of columns outright (possibly all of them)
        let p_zero_col = g.f64(0.0, 1.0);
        for c in 0..cols {
            if g.rng.bool(p_zero_col) {
                for r in 0..rows {
                    w_rm[r * cols + c] = 0.0;
                }
            }
        }
        let w = ColMatrix::from_row_major(rows, cols, &w_rm);
        let dense = FcExec::with_kernel(w.clone(), false, 0.0, KernelChoice::Dense);
        let csc = FcExec::with_kernel(w, false, 0.0, KernelChoice::Csc);
        let batch: Vec<Vec<f32>> = (0..3).map(|_| g.sparse_vec(cols, 0.2)).collect();
        let yd = dense.forward_batch(&batch).map_err(|e| e.to_string())?;
        let yc = csc.forward_batch(&batch).map_err(|e| e.to_string())?;
        if yd != yc {
            return Err("csc != dense with zeroed columns".into());
        }
        // all-zero matrix: output must be exactly zero
        let z = ColMatrix::from_row_major(rows, cols, &vec![0.0; rows * cols]);
        let zc = FcExec::with_kernel(z, false, 0.0, KernelChoice::Csc);
        let yz = zc.forward_batch(&batch).map_err(|e| e.to_string())?;
        if yz.iter().flatten().any(|&v| v != 0.0) {
            return Err("all-zero matrix produced non-zero output".into());
        }
        Ok(())
    });
}

#[test]
fn prop_act_gated_kernels_bit_identical_to_ungated() {
    // The dual-sparsity acceptance property: the activation-gated kernels
    // (skip a stored column when its batch activation slab is all exactly
    // zero) must produce bit-identical outputs to the ungated kernels —
    // for every FC kernel (dense, CSC, CSR, bitmap), across weight
    // sparsity 0.0..=0.99, all-zero activation rows, batch 0/1/64, and
    // eps-thresholded inputs.
    use sonic::plan::{FcExec, KernelChoice};
    check("act-gated == ungated", Config::default(), |g: &mut Gen| {
        let rows = g.dim(1, 24);
        let cols = g.dim(1, 40);
        let wsp = g.f64(0.0, 0.99);
        let w = ColMatrix::from_row_major(rows, cols, &g.sparse_vec(rows * cols, wsp));
        let relu = g.rng.bool(0.5);
        // eps-thresholded inputs: squash |x| <= eps to zero through the
        // shared compression predicate before the kernels see them
        let eps = if g.rng.bool(0.5) { 0.0 } else { 0.05f32 };
        let mk_batch = |g: &mut Gen, bn: usize, asp: f64| -> Vec<Vec<f32>> {
            let mut batch: Vec<Vec<f32>> = (0..bn)
                .map(|_| SparseVec::from_dense_thresh(&g.sparse_vec(cols, asp), eps).to_dense())
                .collect();
            if bn > 1 {
                batch[0] = vec![0.0; cols]; // all-zero activation row
            }
            batch
        };
        for kernel in [
            KernelChoice::Dense,
            KernelChoice::Csc,
            KernelChoice::Csr,
            KernelChoice::Bitmap,
        ] {
            let fc = FcExec::with_kernel(w.clone(), relu, 0.0, kernel);
            for bn in [0usize, 1, g.dim(2, 9), 64] {
                let asp = g.f64(0.0, 1.0);
                let batch = mk_batch(g, bn, asp);
                let gated = fc.forward_batch_gated(&batch, true).map_err(|e| e.to_string())?;
                let ungated =
                    fc.forward_batch_gated(&batch, false).map_err(|e| e.to_string())?;
                if gated != ungated {
                    return Err(format!(
                        "gated != ungated ({kernel:?} rows={rows} cols={cols} \
                         wsp={wsp:.3} asp={asp:.3} batch={bn} eps={eps})"
                    ));
                }
                // the measured auto-gating path must agree too
                let auto = fc.forward_batch(&batch).map_err(|e| e.to_string())?;
                if auto != gated {
                    return Err(format!(
                        "auto-gate != forced ({kernel:?} batch={bn} asp={asp:.3})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csr_and_bitmap_kernels_match_dense_reference() {
    // PR 6 acceptance: the two new compressed kernels (row-major CSR and
    // u64-bitmap), gated and ungated, must equal the dense FcExec
    // reference exactly — same per-element ascending-column accumulation
    // order — across weight density 0.0..=0.99 (sampled to cover the
    // mid-density bitmap band), whole rows/columns zeroed, empty batch,
    // and batch=1.  Rows range past 64 so bitmap masks cross a word
    // boundary.
    use sonic::plan::{FcExec, KernelChoice};
    check("csr/bitmap kernel == dense kernel", Config::default(), |g: &mut Gen| {
        let rows = g.dim(1, 80);
        let cols = g.dim(1, 48);
        let wsp = g.f64(0.0, 0.99);
        let mut w_rm = g.sparse_vec(rows * cols, wsp);
        // zero a random subset of whole columns (dead CSC/bitmap columns)
        // and whole rows (empty CSR rows) outright
        let p_zero = g.f64(0.0, 0.5);
        for c in 0..cols {
            if g.rng.bool(p_zero) {
                for r in 0..rows {
                    w_rm[r * cols + c] = 0.0;
                }
            }
        }
        for r in 0..rows {
            if g.rng.bool(p_zero) {
                w_rm[r * cols..(r + 1) * cols].fill(0.0);
            }
        }
        let w = ColMatrix::from_row_major(rows, cols, &w_rm);
        let relu = g.rng.bool(0.5);
        let dense = FcExec::with_kernel(w.clone(), relu, 0.0, KernelChoice::Dense);
        for kernel in [KernelChoice::Csr, KernelChoice::Bitmap] {
            let fc = FcExec::with_kernel(w.clone(), relu, 0.0, kernel);
            for bn in [0usize, 1, g.dim(2, 9)] {
                let asp = g.f64(0.0, 1.0);
                let mut batch: Vec<Vec<f32>> =
                    (0..bn).map(|_| g.sparse_vec(cols, asp)).collect();
                if bn > 1 {
                    batch[0] = vec![0.0; cols]; // all-zero activation row
                }
                let want = dense.forward_batch(&batch).map_err(|e| e.to_string())?;
                for gate in [Some(true), Some(false), None] {
                    let got = match gate {
                        Some(on) => fc.forward_batch_gated(&batch, on),
                        None => fc.forward_batch(&batch),
                    }
                    .map_err(|e| e.to_string())?;
                    if got != want {
                        return Err(format!(
                            "{kernel:?} != dense (gate={gate:?} rows={rows} cols={cols} \
                             wsp={wsp:.3} asp={asp:.3} batch={bn})"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_plan_executor_matches_serial() {
    // Sharding a batch across pool workers must be bit-identical to the
    // serial kernels, for any batch size vs worker count.
    use sonic::model::ModelDesc;
    use sonic::plan::PlanExecutor;
    use sonic::util::pool::Pool;
    use std::sync::Arc;
    check(
        "pooled executor == serial",
        Config {
            cases: 12,
            ..Default::default()
        },
        |g: &mut Gen| {
            let desc = ModelDesc::builtin("mnist").unwrap();
            let seed = g.rng.range(0, 1 << 20) as u64;
            let serial = PlanExecutor::synthetic(&desc, seed);
            let workers = g.dim(2, 5);
            let par = PlanExecutor::synthetic(&desc, seed)
                .with_pool(Arc::new(Pool::new(workers, 64)));
            let bn = g.dim(1, 7);
            let batch: Vec<Vec<f32>> = (0..bn)
                .map(|_| g.sparse_vec(serial.input_len(), 0.3))
                .collect();
            let a = serial.forward_batch(&batch).map_err(|e| e.to_string())?;
            let b = par.forward_batch(&batch).map_err(|e| e.to_string())?;
            if a != b {
                return Err(format!("parallel != serial (workers={workers} batch={bn})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_plan_batch_latency_monotone_and_bounded() {
    use sonic::model::ModelDesc;
    use sonic::plan::cached;
    check(
        "plan batch math",
        Config {
            cases: 24,
            ..Default::default()
        },
        |g| {
            let name = ["mnist", "cifar10", "svhn"][g.rng.range(0, 3)];
            let mut m = ModelDesc::builtin(name).unwrap();
            let ws = g.f64(0.0, 0.9);
            for l in &mut m.layers {
                l.weight_sparsity = ws;
            }
            let plan = cached(&m, &SonicConfig::paper_best());
            let mut prev = 0.0;
            for b in [1usize, 2, 4, 8, 17, 32] {
                let lat = plan.batch_latency_s(b);
                if lat < prev {
                    return Err(format!("batch {b} latency decreased"));
                }
                if lat < plan.latency_s - 1e-15 || lat > plan.latency_s * b as f64 + 1e-15 {
                    return Err(format!("batch {b} latency out of bounds"));
                }
                prev = lat;
            }
            if (plan.batch_latency_s(1) - plan.latency_s).abs() > 1e-15 {
                return Err("batch 1 != single inference".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simulator_monotonicity_in_sparsity() {
    // more weight sparsity (with compression on) must not increase passes
    use sonic::model::ModelDesc;
    use sonic::sim::simulate;
    check(
        "sim monotone in sparsity",
        Config {
            cases: 16,
            ..Default::default()
        },
        |g| {
            let s1 = g.f64(0.0, 0.5);
            let s2 = s1 + g.f64(0.1, 0.4);
            let mut m1 = ModelDesc::builtin("svhn").unwrap();
            let mut m2 = m1.clone();
            for l in &mut m1.layers {
                l.weight_sparsity = s1;
            }
            for l in &mut m2.layers {
                l.weight_sparsity = s2.min(0.99);
            }
            let cfg = SonicConfig::paper_best();
            let r1 = simulate(&m1, &cfg);
            let r2 = simulate(&m2, &cfg);
            let p1: u64 = r1.layers.iter().map(|l| l.passes).sum();
            let p2: u64 = r2.layers.iter().map(|l| l.passes).sum();
            if p2 > p1 {
                return Err(format!("sparser model has more passes: {p2} > {p1}"));
            }
            if r2.energy_j > r1.energy_j * 1.0001 {
                return Err("sparser model costs more energy".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_qos_tickets_always_resolve() {
    // Any mix of priorities and deadlines (including already-expired
    // ones) must leave no ticket hanging: every request resolves either
    // Served (full logits) or DeadlineExceeded (empty logits, only ever
    // for requests that carried a deadline), and the engine's counters
    // account for every submission.
    use sonic::model::ModelDesc;
    use sonic::serve::{
        BackendChoice, Engine, NullBackend, Outcome, Priority, ServeConfig, SubmitOptions,
    };
    use std::sync::Arc;
    use std::time::Duration;
    check(
        "qos tickets always resolve",
        Config {
            cases: 12,
            max_size: 24,
            ..Default::default()
        },
        |g: &mut Gen| {
            let n = g.dim(1, 24);
            let engine = Engine::builder()
                .serve_config(ServeConfig {
                    max_batch: g.dim(1, 6),
                    batch_window: Duration::from_micros(200),
                    queue_cap: 64,
                    promote_after: if g.rng.bool(0.5) {
                        Duration::ZERO
                    } else {
                        Duration::from_millis(5)
                    },
                    adaptive_window: g.rng.bool(0.5),
                    autotune: false,
                })
                .model_desc(
                    ModelDesc::builtin("mnist").unwrap(),
                    BackendChoice::Custom(Arc::new(NullBackend {
                        input_len: 784,
                        n_classes: 10,
                    })),
                )
                .build()
                .map_err(|e| e.to_string())?;
            let mut tickets = Vec::new();
            for _ in 0..n {
                let priority = match g.rng.range(0, 3) {
                    0 => Priority::High,
                    1 => Priority::Normal,
                    _ => Priority::Batch,
                };
                let deadline = if g.rng.bool(0.4) {
                    Some(Duration::from_millis(g.rng.range(0, 3) as u64))
                } else {
                    None
                };
                let t = engine
                    .submit_opts("mnist", vec![0.5; 784], SubmitOptions { deadline, priority })
                    .map_err(|e| e.to_string())?;
                tickets.push((t, deadline.is_some(), priority));
            }
            engine.shutdown(); // drains everything queued
            for (t, had_deadline, priority) in tickets {
                let c = t.wait().map_err(|e| format!("ticket errored: {e}"))?;
                if c.priority != priority {
                    return Err(format!("completion lane {:?} != {:?}", c.priority, priority));
                }
                match c.outcome {
                    Outcome::Served => {
                        if c.logits.len() != 10 {
                            return Err(format!("served with {} logits", c.logits.len()));
                        }
                    }
                    Outcome::DeadlineExceeded => {
                        if !had_deadline {
                            return Err("shed a request that had no deadline".into());
                        }
                        if !c.logits.is_empty() {
                            return Err("shed completion carries logits".into());
                        }
                    }
                    Outcome::ReplicaFailed => {
                        // cluster-only outcome; a single engine never emits it
                        return Err("single engine emitted ReplicaFailed".into());
                    }
                }
            }
            let m = engine.metrics();
            let mm = m.model("mnist").ok_or("model metrics missing")?;
            if mm.serve.completed + mm.serve.shed != n as u64 {
                return Err(format!(
                    "counters lose requests: {} served + {} shed != {n}",
                    mm.serve.completed, mm.serve.shed
                ));
            }
            let lane_total: u64 = mm
                .lanes
                .iter()
                .map(|l| l.completed + l.shed)
                .sum();
            if lane_total != n as u64 {
                return Err(format!("lane counters lose requests: {lane_total} != {n}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_all_normal_qos_serving_is_bit_identical_to_fixed_fifo() {
    // The QoS router (lanes + adaptive window) must be invisible to a
    // workload that never uses it: same inputs through the default
    // (adaptive) config and the fixed-window FIFO config produce
    // bit-identical logits on the real plan-executor kernels.
    use sonic::model::ModelDesc;
    use sonic::serve::{BackendChoice, Engine, ServeConfig};
    use std::time::Duration;
    check(
        "all-normal qos == fifo",
        Config {
            cases: 6,
            max_size: 12,
            ..Default::default()
        },
        |g: &mut Gen| {
            let n = g.dim(1, 12);
            let inputs: Vec<Vec<f32>> = (0..n).map(|_| g.sparse_vec(784, 0.3)).collect();
            let desc = ModelDesc::builtin("mnist").unwrap();
            let run = |cfg: ServeConfig| -> Result<Vec<Vec<u32>>, String> {
                let engine = Engine::builder()
                    .serve_config(cfg)
                    .synthetic_seed(7)
                    .model_desc(desc.clone(), BackendChoice::Plan)
                    .build()
                    .map_err(|e| e.to_string())?;
                let tickets: Vec<_> = inputs
                    .iter()
                    .map(|x| engine.submit("mnist", x.clone()))
                    .collect::<Result<_, _>>()
                    .map_err(|e| e.to_string())?;
                let out = tickets
                    .into_iter()
                    .map(|t| {
                        t.wait()
                            .map(|c| c.logits.iter().map(|v| v.to_bits()).collect())
                            .map_err(|e| e.to_string())
                    })
                    .collect();
                engine.shutdown();
                out
            };
            let qos = run(ServeConfig::default())?;
            let fifo = run(ServeConfig {
                adaptive_window: false,
                promote_after: Duration::from_secs(3600),
                ..ServeConfig::default()
            })?;
            if qos != fifo {
                return Err(format!("all-Normal serving diverged from FIFO (n={n})"));
            }
            Ok(())
        },
    );
}
