//! Poison-recovery regression (the behavior the `no-lock-unwrap`
//! migration buys): a backend that panics *while holding a lock* must
//! not take down serving.  The engine worker contains the panic and
//! fails only that batch's tickets; every other ticket resolves, the
//! poisoned lock recovers through `util::sync`, and fresh submissions
//! keep serving.  All waits are watchdogged — a hang is a failure, not
//! a stuck CI job.

use sonic::model::ModelDesc;
use sonic::serve::{BackendChoice, Engine, InferenceBackend, Outcome, ServeConfig};
use sonic::util::err::Result;
use sonic::util::sync::LockExt;
use std::sync::{Arc, Mutex};
use std::time::Duration;

const WATCHDOG: Duration = Duration::from_secs(30);
/// Inputs whose first element is the marker make the backend panic.
const MARKER: f32 = 1e6;

/// Probe backend: counts batches under a lock it holds across the
/// batch, and panics on marker inputs *while holding it* — poisoning
/// the mutex exactly the way a buggy backend would under chaos.
struct PoisoningBackend {
    gate: Arc<Mutex<u64>>,
    input_len: usize,
    n_classes: usize,
}

impl InferenceBackend for PoisoningBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut held = self.gate.lock_or_recover();
        *held += 1;
        if inputs.iter().any(|x| x[0] == MARKER) {
            panic!("probe backend: marker input while holding the gate");
        }
        Ok(vec![vec![0.0; self.n_classes]; inputs.len()])
    }

    fn input_len(&self) -> usize {
        self.input_len
    }
}

fn engine_with_gate(gate: Arc<Mutex<u64>>) -> Engine {
    Engine::builder()
        .serve_config(ServeConfig {
            // One request per batch: the marker panics its own batch
            // only, so exactly the marker tickets fail.
            max_batch: 1,
            batch_window: Duration::from_millis(1),
            queue_cap: 64,
            ..ServeConfig::default()
        })
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(Arc::new(PoisoningBackend {
                gate,
                input_len: 784,
                n_classes: 10,
            })),
        )
        .build()
        .unwrap()
}

#[test]
fn panicking_backend_poisons_lock_but_serving_survives() {
    let gate = Arc::new(Mutex::new(0u64));
    let engine = engine_with_gate(Arc::clone(&gate));

    // Interleave healthy requests with two marker (panicking) requests.
    let mut healthy = Vec::new();
    let mut markers = Vec::new();
    for i in 0..12 {
        let mut x = vec![0.5f32; 784];
        if i == 4 || i == 8 {
            x[0] = MARKER;
            markers.push(engine.submit("mnist", x).unwrap());
        } else {
            healthy.push(engine.submit("mnist", x).unwrap());
        }
    }

    // Every marker ticket resolves (no hang) with the contained panic.
    for t in markers {
        let err = t
            .wait_timeout(WATCHDOG)
            .expect_err("marker ticket must fail, not serve");
        assert!(
            format!("{err:#}").contains("panicked"),
            "unexpected failure kind: {err:#}"
        );
    }
    // Every other ticket still resolves served — the poisoned gate
    // recovered instead of cascading.
    for t in healthy {
        let c = t
            .wait_timeout(WATCHDOG)
            .expect("healthy ticket errored")
            .expect("healthy ticket hit the watchdog");
        assert_eq!(c.outcome, Outcome::Served);
    }
    assert!(gate.is_poisoned(), "the marker panic should have poisoned the gate");

    // The engine keeps serving *after* the poison: fresh submissions
    // lock the same poisoned mutex through lock_or_recover.
    for _ in 0..4 {
        let c = engine
            .submit("mnist", vec![0.25; 784])
            .unwrap()
            .wait_timeout(WATCHDOG)
            .expect("post-poison ticket errored")
            .expect("post-poison ticket hit the watchdog");
        assert_eq!(c.outcome, Outcome::Served);
    }
    // The batch counter survived the panic: data behind a poisoned lock
    // stays usable (14 healthy batches + 2 that panicked after the bump).
    assert_eq!(*gate.lock_or_recover(), 16);

    engine.shutdown();
}

#[test]
fn metrics_survive_a_poisoning_backend() {
    let gate = Arc::new(Mutex::new(0u64));
    let engine = engine_with_gate(Arc::clone(&gate));
    let mut x = vec![0.5f32; 784];
    x[0] = MARKER;
    let _ = engine
        .submit("mnist", x)
        .unwrap()
        .wait_timeout(WATCHDOG)
        .expect_err("marker must fail");
    let ok = engine
        .submit("mnist", vec![0.5; 784])
        .unwrap()
        .wait_timeout(WATCHDOG)
        .expect("ticket errored")
        .expect("ticket hit the watchdog");
    assert_eq!(ok.outcome, Outcome::Served);
    // The metrics path walks the same stats locks the panic flew over.
    let m = engine.metrics();
    assert!(!m.models.is_empty(), "metrics must still aggregate");
    engine.shutdown();
}
