//! Cross-module integration tests: dataflow compression feeding the
//! scheduler, scheduler agreeing with the analytic simulator, baselines
//! reproducing the paper's comparative shape, the serve engine over a
//! local backend, and artifact descriptors (when built) agreeing with
//! weight packs.

use std::sync::Arc;
use std::time::Duration;

use sonic::arch::SonicConfig;
use sonic::baselines::all_platforms;
use sonic::coordinator::compress::{compress_fc, fc_product};
use sonic::coordinator::convflow::{conv2d_compressed, CompressedKernel};
use sonic::coordinator::schedule::{schedule_conv, schedule_fc, schedule_layer};
use sonic::model::{LayerKind, ModelDesc};
use sonic::plan::{cached, ModelPlan, PlanExecutor};
use sonic::serve::{BackendChoice, Engine, NullBackend, ServeConfig};
use sonic::sim::{ablation, batch, dse, simulate};
use sonic::sparsity::ColMatrix;
use sonic::tensor::swt;
use sonic::util::rng::Rng;

// ---------------------------------------------------------------------------
// Dataflow: compression -> schedule -> analytic engine reconciliation.

#[test]
fn scheduler_pass_counts_match_analytic_engine_fc() {
    // Build an FC layer matching svhn's fc1792x272 at 50% act sparsity and
    // ~40% weight sparsity, schedule it with real data, and check the pass
    // count against the analytic model's formula.
    let mut rng = Rng::new(11);
    let (out_dim, in_dim) = (272, 1792);
    let act_sparsity = 0.5;
    let w = ColMatrix::from_row_major(out_dim, in_dim, &rng.sparse_vec(out_dim * in_dim, 0.4));
    let a = rng.sparse_vec(in_dim, act_sparsity);
    let compressed = compress_fc(&a, &w);
    let cfg = SonicConfig::paper_best();
    let sched = schedule_fc(&compressed, &cfg);

    // analytic: ceil(L/m) per output
    let kept = a.iter().filter(|&&x| x != 0.0).count();
    let expect = out_dim * kept.div_ceil(cfg.m);
    assert_eq!(sched.passes.len(), expect);

    // activity tracks residual weight sparsity within ~10%
    assert!((sched.activity() - 0.6).abs() < 0.1, "{}", sched.activity());
}

#[test]
fn scheduler_matches_engine_for_conv_slice() {
    let mut rng = Rng::new(12);
    let cfg = SonicConfig::paper_best();
    let (kh, cin, cout) = (3, 8, 4);
    let kvol = kh * kh * cin;
    let weight_sparsity = 0.5;
    let kflat: Vec<Vec<f32>> = (0..cout)
        .map(|_| rng.sparse_vec(kvol, weight_sparsity))
        .collect();
    let kernels: Vec<_> = kflat
        .iter()
        .map(|k| CompressedKernel::from_dense(k))
        .collect();
    let n_px = 16;
    let patches: Vec<Vec<f32>> = (0..n_px).map(|_| rng.normal_vec(kvol)).collect();
    let sched = schedule_conv(&kernels, &patches, &cfg);

    // each kernel has its own dense length; expected = sum over kernels of
    // ceil(len/n) * n_px
    let expect: usize = kernels
        .iter()
        .map(|k| k.values.len().div_ceil(cfg.n).max(1) * n_px)
        .sum();
    assert_eq!(sched.passes.len(), expect);
}

#[test]
fn compressed_fc_product_is_exact_against_direct() {
    let mut rng = Rng::new(13);
    for _ in 0..5 {
        let (rows, cols) = (rng.range(1, 40), rng.range(1, 60));
        let w_rm = rng.sparse_vec(rows * cols, 0.6);
        let a = rng.sparse_vec(cols, 0.5);
        let w = ColMatrix::from_row_major(rows, cols, &w_rm);
        let direct = w.matvec(&a);
        let comp = compress_fc(&a, &w);
        let via = fc_product(&comp);
        for (d, v) in direct.iter().zip(&via) {
            assert!((d - v).abs() < 1e-4);
        }
    }
}

#[test]
fn conv_dataflow_functional_round_trip() {
    // conv through compressed dataflow == dense reference at model scale
    let mut rng = Rng::new(14);
    let (h, w, cin, cout) = (8, 8, 3, 5);
    let x = rng.sparse_vec(h * w * cin, 0.3);
    let kflat: Vec<Vec<f32>> = (0..cout).map(|_| rng.sparse_vec(9 * cin, 0.5)).collect();
    let kernels: Vec<_> = kflat
        .iter()
        .map(|k| CompressedKernel::from_dense(k))
        .collect();
    let y = conv2d_compressed(&x, h, w, cin, &kernels, 3, 3);
    assert_eq!(y.len(), h * w * cout);
    assert!(y.iter().all(|v| v.is_finite()));
}

// ---------------------------------------------------------------------------
// Simulator <-> paper shape.

#[test]
fn paper_fpsw_ratios_within_band() {
    let cfg = SonicConfig::paper_best();
    let platforms = all_platforms();
    let targets = [
        ("NullHop", 5.81),
        ("RSNN", 4.02),
        ("LightBulb", 3.08),
        ("CrossLight", 2.94),
        ("HolyLight", 13.8),
    ];
    for (pname, want) in targets {
        let p = platforms.iter().find(|p| p.name() == pname).unwrap();
        let mut prod = 1.0;
        for name in ["mnist", "cifar10", "stl10", "svhn"] {
            let desc = ModelDesc::load_or_builtin(name);
            let s = simulate(&desc, &cfg);
            prod *= s.fps_per_watt / p.evaluate(&desc).fps_per_watt;
        }
        let gm: f64 = prod.powf(0.25);
        assert!(
            (gm / want - 1.0).abs() < 0.3,
            "{pname}: FPS/W ratio {gm:.2} vs paper {want}"
        );
    }
}

#[test]
fn paper_epb_ratios_within_band() {
    let cfg = SonicConfig::paper_best();
    let platforms = all_platforms();
    let targets = [
        ("NullHop", 8.4),
        ("RSNN", 5.78),
        ("LightBulb", 19.4),
        ("CrossLight", 18.4),
        ("HolyLight", 27.6),
    ];
    for (pname, want) in targets {
        let p = platforms.iter().find(|p| p.name() == pname).unwrap();
        let mut prod = 1.0;
        for name in ["mnist", "cifar10", "stl10", "svhn"] {
            let desc = ModelDesc::load_or_builtin(name);
            let s = simulate(&desc, &cfg);
            prod *= p.evaluate(&desc).epb_j / s.epb_j;
        }
        let gm: f64 = prod.powf(0.25);
        assert!(
            (gm / want - 1.0).abs() < 0.3,
            "{pname}: EPB ratio {gm:.2} vs paper {want}"
        );
    }
}

#[test]
fn sonic_power_sits_between_asics_and_gpus() {
    let cfg = SonicConfig::paper_best();
    let platforms = all_platforms();
    for name in ["mnist", "cifar10", "stl10", "svhn"] {
        let desc = ModelDesc::load_or_builtin(name);
        let s = simulate(&desc, &cfg);
        let nullhop = platforms[0].evaluate(&desc);
        let gpu = platforms[5].evaluate(&desc);
        assert!(s.avg_power_w > nullhop.power_w);
        assert!(s.avg_power_w < gpu.power_w);
    }
}

#[test]
fn paper_geometry_tops_dse_quartile() {
    let models: Vec<ModelDesc> = ["mnist", "cifar10", "svhn"]
        .iter()
        .map(|n| ModelDesc::load_or_builtin(n))
        .collect();
    let points = dse::explore(&models, None);
    let rank = points
        .iter()
        .position(|p| p.geometry() == (5, 50, 50, 10))
        .expect("paper point swept");
    assert!(
        rank < points.len() / 4,
        "paper geometry ranked {rank} of {}",
        points.len()
    );
}

#[test]
fn ablation_all_levers_contribute_on_all_models() {
    for name in ["mnist", "cifar10", "stl10", "svhn"] {
        let rows = ablation::ablate(&ModelDesc::load_or_builtin(name));
        for r in &rows[1..] {
            assert!(
                r.epb_rel >= 1.0 - 1e-9,
                "{name}/{}: ablation improved EPB?",
                r.variant
            );
        }
    }
}

// ---------------------------------------------------------------------------
// LayerPlan IR: one compiled source feeding sim, scheduler, and serving.

#[test]
fn plan_engine_and_scheduler_derive_identical_pass_counts() {
    // The acceptance bar for the refactor: sim, plan, and the data-free
    // scheduler views agree exactly on the dataflow decomposition.
    let cfg = SonicConfig::paper_best();
    for name in ["mnist", "cifar10", "svhn"] {
        let m = ModelDesc::load_or_builtin(name);
        let plan = ModelPlan::compile(&m, &cfg);
        let stats = simulate(&m, &cfg);
        for (lp, ls) in plan.layers.iter().zip(&stats.layers) {
            assert_eq!(lp.passes, ls.passes, "{name}/{}", lp.name);
            assert_eq!(lp.rounds, ls.rounds, "{name}/{}", lp.name);
            assert_eq!(lp.vector_len, ls.vector_len, "{name}/{}", lp.name);
            if !lp.is_conv {
                let sched = schedule_layer(lp);
                assert_eq!(sched.passes.len() as u64, lp.passes, "{name}/{}", lp.name);
                assert_eq!(sched.n_rounds() as u64, lp.rounds, "{name}/{}", lp.name);
            }
        }
    }
}

#[test]
fn served_photonic_accounting_matches_plan_and_batch_model_exactly() {
    let model = ModelDesc::builtin("mnist").unwrap();
    let cfg = SonicConfig::paper_best();
    let plan = cached(&model, &cfg);
    let backend = Arc::new(NullBackend {
        input_len: 784,
        n_classes: 10,
    });
    // max_batch = 1 makes every served batch a singleton regardless of
    // producer/worker timing, so the expected totals are an exact fold of
    // the plan's batch-1 numbers — no wall-clock window to race against.
    let engine = Engine::builder()
        .arch(cfg.clone())
        .serve_config(ServeConfig {
            max_batch: 1,
            batch_window: Duration::from_millis(1),
            queue_cap: 16,
            ..ServeConfig::default()
        })
        .model_desc(model.clone(), BackendChoice::Custom(backend))
        .build()
        .unwrap();
    let tickets: Vec<_> = (0..4)
        .map(|_| engine.submit("mnist", vec![1.0; 784]).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    engine.shutdown();
    let m = engine.metrics();
    let m = &m.model("mnist").unwrap().serve;
    assert_eq!(m.completed, 4);
    assert_eq!(m.batches, 4, "max_batch=1 -> singleton batches");

    // served == plan, bit-for-bit: no drift possible.
    let expect_t = (0..4).fold(0.0, |acc, _| acc + plan.batch_latency_s(1));
    let expect_e = (0..4).fold(0.0, |acc, _| acc + plan.batch_energy_j(1));
    assert_eq!(m.photonic_time_s, expect_t);
    assert_eq!(m.photonic_energy_j, expect_e);

    // and the plan's batch amortization is exactly what sim::batch reports
    // (pure functions of the same compiled plan, no serving timing).
    let bs = batch::batched(&model, &cfg, 4);
    assert_eq!(bs.latency_s, plan.batch_latency_s(4));
    assert_eq!(bs.energy_j, plan.batch_energy_j(4));
}

#[test]
fn plan_cache_shared_between_engine_and_simulator() {
    let model = ModelDesc::builtin("svhn").unwrap();
    let cfg = SonicConfig::paper_best();
    let direct = cached(&model, &cfg);
    let backend = Arc::new(NullBackend {
        input_len: model.input_len(),
        n_classes: 10,
    });
    let engine = Engine::builder()
        .arch(cfg)
        .model_desc(model, BackendChoice::Custom(backend))
        .build()
        .unwrap();
    assert!(Arc::ptr_eq(&engine.plan("svhn").unwrap(), &direct));
}

#[test]
fn engine_serves_through_plan_backend() {
    // Functional serving with zero PJRT: batched sparse kernels over the
    // compiled plan layout, selected by BackendChoice::Plan.
    let desc = ModelDesc::builtin("mnist").unwrap();
    let n_classes = desc.n_classes;
    let engine = Engine::builder()
        .serve_config(ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(2),
            queue_cap: 64,
            ..ServeConfig::default()
        })
        .synthetic_seed(11)
        .model_desc(desc.clone(), BackendChoice::Plan)
        .build()
        .unwrap();
    assert_eq!(engine.backend_kind("mnist").unwrap(), "plan");
    let input_len = engine.input_len("mnist").unwrap();
    assert_eq!(input_len, desc.input_len());
    let mut rng = Rng::new(13);
    let tickets: Vec<_> = (0..8)
        .map(|_| engine.submit("mnist", rng.normal_vec(input_len)).unwrap())
        .collect();
    for t in tickets {
        let c = t.wait().unwrap();
        assert_eq!(c.logits.len(), n_classes);
        assert!(c.logits.iter().all(|v| v.is_finite()));
    }
    engine.shutdown();
    let m = engine.metrics();
    let m = m.model("mnist").unwrap();
    assert_eq!(m.serve.completed, 8);
    assert!(m.serve.photonic_fps() > 0.0);
    assert!(m.p99 >= m.p50);
    // The plan backend measures activation density: every batch must have
    // been charged against a measured-density plan, and the per-layer
    // breakdown must surface what was measured.
    assert_eq!(m.serve.measured_batches, m.serve.batches);
    assert_eq!(m.kernel_breakdown.len(), desc.layers.len());
    for l in &m.kernel_breakdown {
        let d = l.act_density.expect("plan backend measures density");
        assert!((0.0..=1.0).contains(&d), "{}: {d}", l.layer);
    }
}

#[test]
fn plan_executor_batch_equals_one_by_one() {
    // Batched execution must be a pure reordering of per-request work.
    let desc = ModelDesc::builtin("svhn").unwrap();
    let ex = PlanExecutor::synthetic(&desc, 17);
    let mut rng = Rng::new(18);
    let inputs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(ex.input_len())).collect();
    let batched = ex.forward_batch(&inputs).unwrap();
    for (x, want) in inputs.iter().zip(&batched) {
        let single = ex.forward_batch(std::slice::from_ref(x)).unwrap();
        for (a, b) in single[0].iter().zip(want) {
            assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0), "{a} vs {b}");
        }
    }
}

// ---------------------------------------------------------------------------
// Engine over a local backend (PJRT-free serving path).

#[test]
fn engine_serves_a_stream_end_to_end() {
    let model = ModelDesc::builtin("svhn").unwrap();
    let input_len = model.input_hw * model.input_hw * model.input_ch;
    let backend = Arc::new(NullBackend {
        input_len,
        n_classes: 10,
    });
    let engine = Engine::builder()
        .serve_config(ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(2),
            queue_cap: 256,
            ..ServeConfig::default()
        })
        .model_desc(model, BackendChoice::Custom(backend))
        .build()
        .unwrap();
    let mut rng = Rng::new(5);
    let tickets: Vec<_> = (0..32)
        .map(|_| engine.submit("svhn", rng.normal_vec(input_len)).unwrap())
        .collect();
    for t in &tickets {
        t.wait().unwrap();
    }
    engine.shutdown();
    let m = engine.metrics();
    let metrics = &m.model("svhn").unwrap().serve;
    assert_eq!(metrics.completed, 32);
    assert!(metrics.batches <= 32);
    assert!(metrics.photonic_fps() > 0.0);
    assert!(metrics.mean_batch() >= 1.0);
}

// ---------------------------------------------------------------------------
// Artifact agreement (skipped until `make artifacts` has produced them).

#[test]
fn artifact_descriptors_agree_with_weight_packs() {
    let art = sonic::artifacts_dir();
    if !art.join("mnist.json").is_file() || !art.join("mnist.swt").is_file() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    for name in ["mnist", "cifar10", "svhn"] {
        let desc = ModelDesc::load(&art.join(format!("{name}.json"))).unwrap();
        let tensors = swt::read_swt(&art.join(format!("{name}.swt"))).unwrap();
        // One w/b/scale/bias quartet per layer.
        assert_eq!(tensors.len(), desc.layers.len() * 4, "{name}");
        // Descriptor sparsity matches the actual weight tensors.
        for layer in &desc.layers {
            let w = tensors
                .iter()
                .find(|t| t.name == format!("{}.w", layer.name))
                .unwrap_or_else(|| panic!("{name}: missing {}.w", layer.name));
            assert!(
                (w.sparsity() - layer.weight_sparsity).abs() < 0.02,
                "{name}/{}: swt {:.3} vs descriptor {:.3}",
                layer.name,
                w.sparsity(),
                layer.weight_sparsity
            );
            assert!(
                w.unique_nonzero() <= desc.n_clusters,
                "{name}/{}: {} unique > {} clusters",
                layer.name,
                w.unique_nonzero(),
                desc.n_clusters
            );
        }
        // Layer geometry agrees with Table 1 reconstruction.
        let b = ModelDesc::builtin(name).unwrap();
        assert_eq!(desc.layers.len(), b.layers.len(), "{name}");
        for (l, bl) in desc.layers.iter().zip(&b.layers) {
            match (&l.kind, &bl.kind) {
                (
                    LayerKind::Conv { kernel, in_ch, out_ch, .. },
                    LayerKind::Conv {
                        kernel: bk,
                        in_ch: bi,
                        out_ch: bo,
                        ..
                    },
                ) => {
                    assert_eq!((kernel, in_ch, out_ch), (bk, bi, bo), "{name}");
                }
                (
                    LayerKind::Fc { in_dim, out_dim, .. },
                    LayerKind::Fc {
                        in_dim: bi,
                        out_dim: bo,
                        ..
                    },
                ) => {
                    assert_eq!((in_dim, out_dim), (bi, bo), "{name}");
                }
                _ => panic!("{name}: layer kind mismatch"),
            }
        }
    }
}

#[test]
fn measured_sparsity_feeds_simulator_consistently() {
    // When measured descriptors exist, the simulator must still produce the
    // paper's comparative shape with them (not just with builtin numbers).
    let art = sonic::artifacts_dir();
    if !art.join("cifar10.json").is_file() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let cfg = SonicConfig::paper_best();
    let measured = ModelDesc::load(&art.join("cifar10.json")).unwrap();
    let s = simulate(&measured, &cfg);
    let dense_cfg = SonicConfig::paper_best()
        .without_power_gating()
        .without_compression()
        .without_clustering();
    let d = simulate(&measured, &dense_cfg);
    assert!(s.fps_per_watt > d.fps_per_watt * 2.0);
    assert!(s.epb_j < d.epb_j);
}
