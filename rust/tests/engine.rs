//! `sonic::serve::Engine` integration tests: handle-based submission,
//! multi-model routing, backpressure, graceful shutdown, and per-model
//! photonic accounting agreeing with the compiled plan.

use sonic::util::sync::LockExt;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use sonic::arch::SonicConfig;
use sonic::model::ModelDesc;
use sonic::plan::cached;
use sonic::serve::{
    BackendChoice, Engine, InferenceBackend, NullBackend, ServeConfig,
};
use sonic::util::err::Result;

fn null_backend(input_len: usize) -> Arc<NullBackend> {
    Arc::new(NullBackend {
        input_len,
        n_classes: 10,
    })
}

/// Backend whose batches block while the test holds `gate` — makes
/// queue-full and in-flight states deterministic.
struct GatedBackend {
    gate: Arc<Mutex<()>>,
    inner: NullBackend,
}

impl InferenceBackend for GatedBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let _g = self.gate.lock_or_recover();
        self.inner.infer_batch(inputs)
    }
    fn input_len(&self) -> usize {
        self.inner.input_len()
    }
}

#[test]
fn ticket_wait_returns_the_matching_requests_logits() {
    // NullBackend: logits[c] = sum of x[i] with i % 10 == c.  A one-hot
    // input at position j therefore yields exactly logits[j % 10] == 1.0,
    // so each ticket proves it carried *its* request through the batcher.
    let engine = Engine::builder()
        .serve_config(ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(1),
            queue_cap: 64,
            ..ServeConfig::default()
        })
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(null_backend(784)),
        )
        .build()
        .unwrap();
    let tickets: Vec<_> = (0..20)
        .map(|j| {
            let mut x = vec![0.0f32; 784];
            x[j] = 1.0;
            engine.submit("mnist", x).unwrap()
        })
        .collect();
    for (j, t) in tickets.into_iter().enumerate() {
        let c = t.wait().unwrap();
        assert_eq!(c.argmax, j % 10, "ticket {j} got another request's logits");
        assert!((c.logits[j % 10] - 1.0).abs() < 1e-6);
    }
}

#[test]
fn concurrent_submitters_across_two_models() {
    let mnist = ModelDesc::builtin("mnist").unwrap();
    let svhn = ModelDesc::builtin("svhn").unwrap();
    let svhn_len = svhn.input_len();
    let engine = Arc::new(
        Engine::builder()
            .serve_config(ServeConfig {
                max_batch: 8,
                batch_window: Duration::from_millis(1),
                queue_cap: 256,
                ..ServeConfig::default()
            })
            .model_desc(mnist, BackendChoice::Custom(null_backend(784)))
            .model_desc(svhn, BackendChoice::Custom(null_backend(svhn_len)))
            .build()
            .unwrap(),
    );
    let mut handles = Vec::new();
    for w in 0..4 {
        let engine = Arc::clone(&engine);
        handles.push(std::thread::spawn(move || {
            for i in 0..10 {
                let (model, len) = if (w + i) % 2 == 0 {
                    ("mnist", 784)
                } else {
                    ("svhn", svhn_len)
                };
                let c = engine.submit(model, vec![0.5; len]).unwrap().wait().unwrap();
                assert_eq!(c.logits.len(), 10);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = engine.metrics();
    assert_eq!(m.completed(), 40);
    assert_eq!(m.model("mnist").unwrap().serve.completed, 20);
    assert_eq!(m.model("svhn").unwrap().serve.completed, 20);
}

#[test]
fn per_model_photonic_metrics_match_cached_plans() {
    // Acceptance: one engine serving two models concurrently, each model's
    // photonic accounting equal to its own compiled plan's numbers.
    // max_batch = 1 makes every batch size-1, so the expected totals are
    // an exact fold of plan.batch_latency_s(1) / batch_energy_j(1).
    let cfg = SonicConfig::paper_best();
    let mnist = ModelDesc::builtin("mnist").unwrap();
    let svhn = ModelDesc::builtin("svhn").unwrap();
    let svhn_len = svhn.input_len();
    let engine = Arc::new(
        Engine::builder()
            .arch(cfg.clone())
            .serve_config(ServeConfig {
                max_batch: 1,
                batch_window: Duration::from_millis(1),
                queue_cap: 256,
                ..ServeConfig::default()
            })
            .model_desc(mnist.clone(), BackendChoice::Custom(null_backend(784)))
            .model_desc(svhn.clone(), BackendChoice::Custom(null_backend(svhn_len)))
            .build()
            .unwrap(),
    );
    let (n_mnist, n_svhn) = (12u64, 7u64);
    let t1 = {
        let engine = Arc::clone(&engine);
        std::thread::spawn(move || {
            let tickets: Vec<_> = (0..n_mnist)
                .map(|_| engine.submit("mnist", vec![1.0; 784]).unwrap())
                .collect();
            for t in tickets {
                t.wait().unwrap();
            }
        })
    };
    let tickets: Vec<_> = (0..n_svhn)
        .map(|_| engine.submit("svhn", vec![1.0; svhn_len]).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    t1.join().unwrap();
    engine.shutdown();

    let m = engine.metrics();
    for (name, desc, n) in [("mnist", &mnist, n_mnist), ("svhn", &svhn, n_svhn)] {
        let plan = cached(desc, &cfg);
        assert!(Arc::ptr_eq(&engine.plan(name).unwrap(), &plan));
        let mm = m.model(name).unwrap();
        assert_eq!(mm.serve.completed, n, "{name}");
        assert_eq!(mm.serve.batches, n, "{name}: max_batch=1");
        let expect_t = (0..n).fold(0.0, |acc, _| acc + plan.batch_latency_s(1));
        let expect_e = (0..n).fold(0.0, |acc, _| acc + plan.batch_energy_j(1));
        assert_eq!(mm.serve.photonic_time_s, expect_t, "{name}");
        assert_eq!(mm.serve.photonic_energy_j, expect_e, "{name}");
        // EPB in the snapshot is energy over bits moved for this model
        let want_epb =
            mm.serve.photonic_energy_j / (n as f64 * plan.bits_per_inference);
        assert!((mm.photonic_epb_j - want_epb).abs() < want_epb * 1e-12, "{name}");
    }
}

#[test]
fn shutdown_completes_all_in_flight_tickets() {
    let gate = Arc::new(Mutex::new(()));
    let backend = Arc::new(GatedBackend {
        gate: Arc::clone(&gate),
        inner: NullBackend {
            input_len: 784,
            n_classes: 10,
        },
    });
    let engine = Arc::new(
        Engine::builder()
            .serve_config(ServeConfig {
                max_batch: 4,
                batch_window: Duration::from_millis(1),
                queue_cap: 64,
                ..ServeConfig::default()
            })
            .model_desc(
                ModelDesc::builtin("mnist").unwrap(),
                BackendChoice::Custom(backend),
            )
            .build()
            .unwrap(),
    );
    // Hold the gate so everything stays queued or in flight, then shut
    // down while requests are pending.
    let tickets: Vec<_> = {
        let _held = gate.lock_or_recover();
        let tickets: Vec<_> = (0..16)
            .map(|_| engine.submit("mnist", vec![0.1; 784]).unwrap())
            .collect();
        // shutdown() must block until the queue drains
        let shutdown = {
            let engine = Arc::clone(&engine);
            std::thread::spawn(move || engine.shutdown())
        };
        drop(_held); // release the backend; drain proceeds
        shutdown.join().unwrap();
        tickets
    };
    for t in &tickets {
        let c = t.wait().expect("in-flight ticket must complete at shutdown");
        assert_eq!(c.logits.len(), 10);
    }
    // post-shutdown: the engine refuses new work
    let e = engine.submit("mnist", vec![0.0; 784]).unwrap_err();
    assert!(e.to_string().contains("shut down"), "{e}");
    assert_eq!(engine.metrics().completed(), 16);
}

#[test]
fn full_queue_backpressure_try_submit_returns_none_then_recovers() {
    let gate = Arc::new(Mutex::new(()));
    let backend = Arc::new(GatedBackend {
        gate: Arc::clone(&gate),
        inner: NullBackend {
            input_len: 784,
            n_classes: 10,
        },
    });
    let engine = Engine::builder()
        .serve_config(ServeConfig {
            max_batch: 1,
            batch_window: Duration::from_millis(1),
            queue_cap: 2,
            ..ServeConfig::default()
        })
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(backend),
        )
        .build()
        .unwrap();
    let mut tickets = Vec::new();
    let saw_full = {
        let _held = gate.lock_or_recover();
        let mut saw_full = false;
        // worker blocks on the gated batch; cap-2 queue must fill
        for _ in 0..50 {
            match engine.try_submit("mnist", vec![0.2; 784]).unwrap() {
                Some(t) => tickets.push(t),
                None => {
                    saw_full = true;
                    break;
                }
            }
        }
        saw_full
    };
    assert!(saw_full, "try_submit never reported a full queue");
    assert!(tickets.len() >= 2, "queue_cap requests were accepted first");
    // gate released: everything accepted so far must complete
    for t in &tickets {
        t.wait().unwrap();
    }
    // and a blocking submit goes straight through again
    let c = engine.submit("mnist", vec![0.3; 784]).unwrap().wait().unwrap();
    assert_eq!(c.logits.len(), 10);
    engine.shutdown();
}

#[test]
fn bad_inputs_error_instead_of_panicking() {
    let engine = Engine::builder()
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(null_backend(784)),
        )
        .build()
        .unwrap();
    let e = engine.submit("mnist", vec![0.0; 3]).unwrap_err();
    assert!(e.to_string().contains("bad input length"), "{e}");
    let e = engine.submit("nope", vec![0.0; 784]).unwrap_err();
    assert!(e.to_string().contains("not registered"), "{e}");
    // the engine still serves fine afterwards
    engine.submit("mnist", vec![0.0; 784]).unwrap().wait().unwrap();
}

#[test]
fn short_output_backend_fails_tickets_instead_of_hanging() {
    // A Custom backend violating the one-output-per-input contract must
    // fail the whole batch's tickets, not silently drop the tail.
    struct ShortBackend;
    impl InferenceBackend for ShortBackend {
        fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Ok(inputs.iter().skip(1).map(|_| vec![0.0; 10]).collect())
        }
        fn input_len(&self) -> usize {
            784
        }
    }
    let engine = Engine::builder()
        .serve_config(ServeConfig {
            max_batch: 2,
            batch_window: Duration::from_millis(50),
            queue_cap: 8,
            ..ServeConfig::default()
        })
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(Arc::new(ShortBackend)),
        )
        .build()
        .unwrap();
    let t1 = engine.submit("mnist", vec![0.0; 784]).unwrap();
    let t2 = engine.submit("mnist", vec![0.0; 784]).unwrap();
    for t in [t1, t2] {
        let e = t.wait().unwrap_err();
        assert!(e.to_string().contains("outputs"), "{e}");
    }
    engine.shutdown();
}

#[test]
fn builder_rejects_unknown_model_name() {
    let e = Engine::builder()
        .model("not-a-model", BackendChoice::Plan)
        .build()
        .unwrap_err();
    assert!(e.to_string().contains("unknown model"), "{e}");
}

#[test]
fn builder_rejects_empty_and_duplicate_registration() {
    assert!(Engine::builder().build().is_err());
    let e = Engine::builder()
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(null_backend(784)),
        )
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(null_backend(784)),
        )
        .build()
        .unwrap_err();
    assert!(e.to_string().contains("twice"), "{e}");
}

#[test]
fn panicking_backend_fails_its_tickets_but_worker_survives() {
    use std::sync::atomic::{AtomicBool, Ordering};
    struct PanicOnFirst {
        tripped: AtomicBool,
        inner: NullBackend,
    }
    impl InferenceBackend for PanicOnFirst {
        fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            if !self.tripped.swap(true, Ordering::SeqCst) {
                panic!("kaboom");
            }
            self.inner.infer_batch(inputs)
        }
        fn input_len(&self) -> usize {
            self.inner.input_len()
        }
    }
    let engine = Engine::builder()
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(Arc::new(PanicOnFirst {
                tripped: AtomicBool::new(false),
                inner: NullBackend {
                    input_len: 784,
                    n_classes: 10,
                },
            })),
        )
        .build()
        .unwrap();
    // first batch panics: its ticket must resolve to an error, not hang
    let e = engine
        .submit("mnist", vec![0.0; 784])
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(e.to_string().contains("panicked"), "{e}");
    // the worker thread survived the panic and keeps serving the model
    let c = engine.submit("mnist", vec![0.0; 784]).unwrap().wait().unwrap();
    assert_eq!(c.logits.len(), 10);
    engine.shutdown();
}

#[test]
fn try_wait_polls_without_blocking() {
    let gate = Arc::new(Mutex::new(()));
    let backend = Arc::new(GatedBackend {
        gate: Arc::clone(&gate),
        inner: NullBackend {
            input_len: 784,
            n_classes: 10,
        },
    });
    let engine = Engine::builder()
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(backend),
        )
        .build()
        .unwrap();
    let t = {
        let _held = gate.lock_or_recover();
        let t = engine.submit("mnist", vec![0.0; 784]).unwrap();
        assert!(t.try_wait().unwrap().is_none(), "gated request already done?");
        t
    };
    let c = t.wait().unwrap();
    assert_eq!(c.logits.len(), 10);
    assert!(t.try_wait().unwrap().is_some());
    engine.shutdown();
}

// ---------------------------------------------------------------------------
// QoS: priority lanes, deadline shedding, starvation guard, FIFO parity.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::Instant;

use sonic::serve::{Outcome, Priority, SubmitOptions};

/// Backend that records `input[0]` of every row it executes (in drain
/// order), counts rows, signals batch entry, and blocks on `gate` while
/// the test holds it — makes queue states and drain order deterministic.
struct ProbeBackend {
    gate: Arc<Mutex<()>>,
    entered: Arc<AtomicBool>,
    markers: Arc<Mutex<Vec<i64>>>,
    rows: Arc<AtomicUsize>,
    inner: NullBackend,
}

impl ProbeBackend {
    fn new(gate: Arc<Mutex<()>>) -> Self {
        Self {
            gate,
            entered: Arc::new(AtomicBool::new(false)),
            markers: Arc::new(Mutex::new(Vec::new())),
            rows: Arc::new(AtomicUsize::new(0)),
            inner: NullBackend {
                input_len: 784,
                n_classes: 10,
            },
        }
    }
}

impl InferenceBackend for ProbeBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        {
            let mut m = self.markers.lock_or_recover();
            for x in inputs {
                m.push(x[0] as i64);
            }
        }
        self.rows.fetch_add(inputs.len(), Ordering::SeqCst);
        self.entered.store(true, Ordering::SeqCst);
        let _g = self.gate.lock_or_recover();
        self.inner.infer_batch(inputs)
    }
    fn input_len(&self) -> usize {
        self.inner.input_len
    }
}

fn marked(marker: i64) -> Vec<f32> {
    let mut x = vec![0.0f32; 784];
    x[0] = marker as f32;
    x
}

fn wait_entered(flag: &AtomicBool) {
    let t0 = Instant::now();
    while !flag.load(Ordering::SeqCst) {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "worker never entered the backend"
        );
        std::thread::sleep(Duration::from_micros(200));
    }
}

fn probe_engine(cfg: ServeConfig, gate: Arc<Mutex<()>>) -> (Engine, Arc<ProbeBackend>) {
    let backend = Arc::new(ProbeBackend::new(gate));
    let engine = Engine::builder()
        .serve_config(cfg)
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(Arc::clone(&backend) as Arc<dyn InferenceBackend>),
        )
        .build()
        .unwrap();
    (engine, backend)
}

#[test]
fn expired_requests_are_shed_before_reaching_the_backend() {
    let gate = Arc::new(Mutex::new(()));
    let (engine, backend) = probe_engine(
        ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(1),
            queue_cap: 64,
            ..ServeConfig::default()
        },
        Arc::clone(&gate),
    );
    let (holder, doomed) = {
        let _held = gate.lock_or_recover();
        let holder = engine.submit("mnist", marked(0)).unwrap();
        wait_entered(&backend.entered);
        // Worker is blocked inside the backend; these queue up with an
        // already-expired deadline and must be shed at the next drain.
        let doomed: Vec<_> = (0..5)
            .map(|i| {
                engine
                    .submit_opts(
                        "mnist",
                        marked(100 + i),
                        SubmitOptions::with_deadline(Duration::ZERO),
                    )
                    .unwrap()
            })
            .collect();
        (holder, doomed)
    };
    let c = holder.wait().unwrap();
    assert_eq!(c.outcome, Outcome::Served);
    for t in doomed {
        let c = t.wait().expect("shed ticket resolves");
        assert_eq!(c.outcome, Outcome::DeadlineExceeded);
    }
    engine.shutdown();
    let m = engine.metrics();
    let mm = m.model("mnist").unwrap();
    assert_eq!(mm.serve.shed, 5, "all expired requests shed");
    assert_eq!(mm.serve.completed, 1, "only the holder executed");
    assert_eq!(mm.lanes[Priority::Normal.idx()].shed, 5);
    assert_eq!(
        backend.rows.load(Ordering::SeqCst),
        1,
        "expired requests must never reach the backend"
    );
}

#[test]
fn shed_tickets_resolve_with_deadline_exceeded_completions() {
    let gate = Arc::new(Mutex::new(()));
    let (engine, backend) = probe_engine(
        ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(1),
            queue_cap: 64,
            ..ServeConfig::default()
        },
        Arc::clone(&gate),
    );
    let (holder, doomed) = {
        let _held = gate.lock_or_recover();
        let holder = engine.submit("mnist", marked(0)).unwrap();
        wait_entered(&backend.entered);
        let doomed: Vec<_> = (0..3)
            .map(|i| {
                engine
                    .submit_opts(
                        "mnist",
                        marked(100 + i),
                        SubmitOptions {
                            deadline: Some(Duration::ZERO),
                            priority: Priority::Batch,
                        },
                    )
                    .unwrap()
            })
            .collect();
        (holder, doomed)
    };
    holder.wait().unwrap();
    for t in doomed {
        let c = t.wait().expect("shed ticket must resolve, not error");
        assert_eq!(c.outcome, Outcome::DeadlineExceeded);
        assert!(!c.served());
        assert!(c.logits.is_empty());
        assert_eq!(c.priority, Priority::Batch);
        assert_eq!(c.photonic_latency_s, 0.0, "shed requests charge nothing");
    }
    engine.shutdown();
    assert_eq!(engine.metrics().model("mnist").unwrap().serve.shed, 3);
    assert_eq!(backend.rows.load(Ordering::SeqCst), 1);
}

#[test]
fn priority_lanes_serve_high_before_batch_under_load() {
    let gate = Arc::new(Mutex::new(()));
    let (engine, backend) = probe_engine(
        ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(1),
            queue_cap: 64,
            // lanes must not age into promotion during this test
            promote_after: Duration::from_secs(10),
            ..ServeConfig::default()
        },
        Arc::clone(&gate),
    );
    let tickets = {
        let _held = gate.lock_or_recover();
        let mut tickets = vec![engine.submit("mnist", marked(0)).unwrap()];
        wait_entered(&backend.entered);
        // Queue fills while the worker is gated: Batch lane first, then
        // High — the drain must still serve every High request first.
        for i in 0..6 {
            tickets.push(
                engine
                    .submit_opts(
                        "mnist",
                        marked(100 + i),
                        SubmitOptions::with_priority(Priority::Batch),
                    )
                    .unwrap(),
            );
        }
        for i in 0..6 {
            tickets.push(
                engine
                    .submit_opts(
                        "mnist",
                        marked(200 + i),
                        SubmitOptions::with_priority(Priority::High),
                    )
                    .unwrap(),
            );
        }
        tickets
    };
    for t in tickets {
        t.wait().unwrap();
    }
    engine.shutdown();
    let order = backend.markers.lock_or_recover().clone();
    assert_eq!(order.len(), 13);
    assert_eq!(order[0], 0, "gated holder executes first");
    let highs: Vec<usize> = order
        .iter()
        .enumerate()
        .filter(|(_, m)| (200..300).contains(*m))
        .map(|(i, _)| i)
        .collect();
    let batches: Vec<usize> = order
        .iter()
        .enumerate()
        .filter(|(_, m)| (100..200).contains(*m))
        .map(|(i, _)| i)
        .collect();
    assert_eq!((highs.len(), batches.len()), (6, 6));
    assert!(
        highs.iter().max() < batches.iter().min(),
        "a Batch request ran before a High request: {order:?}"
    );
    // FIFO within each lane
    let high_vals: Vec<i64> = order.iter().copied().filter(|m| (200..300).contains(m)).collect();
    let batch_vals: Vec<i64> = order.iter().copied().filter(|m| (100..200).contains(m)).collect();
    assert_eq!(high_vals, (200..206).collect::<Vec<i64>>());
    assert_eq!(batch_vals, (100..106).collect::<Vec<i64>>());
    let m = engine.metrics();
    let mm = m.model("mnist").unwrap();
    assert_eq!(mm.lanes[Priority::High.idx()].completed, 6);
    assert_eq!(mm.lanes[Priority::Batch.idx()].completed, 6);
}

#[test]
fn starvation_guard_promotes_aged_batch_lane() {
    let gate = Arc::new(Mutex::new(()));
    let (engine, backend) = probe_engine(
        ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_millis(1),
            queue_cap: 64,
            // ZERO degenerates to strict oldest-first across lanes: the
            // deterministic form of "an aged lane is drained first".
            promote_after: Duration::ZERO,
            ..ServeConfig::default()
        },
        Arc::clone(&gate),
    );
    let tickets = {
        let _held = gate.lock_or_recover();
        let mut tickets = vec![engine.submit("mnist", marked(0)).unwrap()];
        wait_entered(&backend.entered);
        tickets.push(
            engine
                .submit_opts(
                    "mnist",
                    marked(100),
                    SubmitOptions::with_priority(Priority::Batch),
                )
                .unwrap(),
        );
        std::thread::sleep(Duration::from_millis(2));
        for i in 0..2 {
            tickets.push(
                engine
                    .submit_opts(
                        "mnist",
                        marked(200 + i),
                        SubmitOptions::with_priority(Priority::High),
                    )
                    .unwrap(),
            );
        }
        tickets
    };
    for t in tickets {
        t.wait().unwrap();
    }
    engine.shutdown();
    let order = backend.markers.lock_or_recover().clone();
    assert_eq!(
        order,
        vec![0, 100, 200, 201],
        "aged Batch head must be promoted over the High lane"
    );
    let m = engine.metrics();
    assert!(
        m.model("mnist").unwrap().lanes[Priority::Batch.idx()].promoted >= 1,
        "starvation-guard promotion not counted"
    );
}

#[test]
fn all_normal_no_deadline_matches_fixed_fifo_bit_identically() {
    // Acceptance: a workload that never uses the QoS surface must produce
    // completions bit-identical to the pre-change FIFO router (modelled
    // by adaptive_window = false — the fixed-window single-lane drain).
    fn run(cfg: ServeConfig) -> Vec<(usize, Vec<u32>)> {
        use sonic::util::rng::Rng;
        let engine = Engine::builder()
            .serve_config(cfg)
            .synthetic_seed(7)
            .model("mnist", BackendChoice::Plan)
            .build()
            .unwrap();
        let mut rng = Rng::new(5);
        let tickets: Vec<_> = (0..24)
            .map(|_| engine.submit("mnist", rng.normal_vec(784)).unwrap())
            .collect();
        let out = tickets
            .into_iter()
            .map(|t| {
                let c = t.wait().unwrap();
                assert_eq!(c.outcome, Outcome::Served);
                (c.argmax, c.logits.iter().map(|v| v.to_bits()).collect())
            })
            .collect();
        engine.shutdown();
        out
    }
    let qos = run(ServeConfig::default());
    let fifo = run(ServeConfig {
        adaptive_window: false,
        promote_after: Duration::from_secs(3600),
        ..ServeConfig::default()
    });
    assert_eq!(
        qos, fifo,
        "all-Normal/no-deadline serving diverged from the FIFO router"
    );
}

#[test]
fn shutdown_racing_submitters_never_hangs_a_ticket() {
    // Regression for the race noted at serve/engine.rs submit_inner: a
    // request enqueued as shutdown() begins must either complete or
    // resolve its Ticket with an error — wait() may never hang.
    let engine = Arc::new(
        Engine::builder()
            .serve_config(ServeConfig {
                max_batch: 2,
                batch_window: Duration::from_micros(200),
                queue_cap: 8,
                ..ServeConfig::default()
            })
            .model_desc(
                ModelDesc::builtin("mnist").unwrap(),
                BackendChoice::Custom(null_backend(784)),
            )
            .build()
            .unwrap(),
    );
    let stop = Arc::new(AtomicBool::new(false));
    let mut producers = Vec::new();
    for w in 0..4u64 {
        let engine = Arc::clone(&engine);
        let stop = Arc::clone(&stop);
        producers.push(std::thread::spawn(move || {
            let mut got = Vec::new();
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                // half the producers block (backpressure path), half poll
                let r = if w % 2 == 0 {
                    engine.submit("mnist", vec![0.1; 784]).map(Some)
                } else {
                    engine.try_submit("mnist", vec![0.1; 784])
                };
                match r {
                    Ok(Some(t)) => got.push(t),
                    Ok(None) => std::thread::yield_now(),
                    Err(_) => break, // engine shut down — expected
                }
            }
            got
        }));
    }
    std::thread::sleep(Duration::from_millis(20));
    engine.shutdown();
    stop.store(true, Ordering::SeqCst);
    let tickets: Vec<_> = producers
        .into_iter()
        .flat_map(|h| h.join().unwrap())
        .collect();
    assert!(!tickets.is_empty(), "race test produced no tickets");
    // Every ticket must resolve promptly after shutdown returned — run
    // the waits on a watchdog thread so a hang fails instead of wedging
    // the test binary.
    let (tx, rx) = std::sync::mpsc::channel();
    let waiter = std::thread::spawn(move || {
        let mut served = 0u64;
        let mut failed = 0u64;
        for t in tickets {
            match t.wait() {
                Ok(c) => {
                    assert_eq!(c.logits.len(), 10);
                    served += 1;
                }
                Err(e) => {
                    assert!(e.to_string().contains("shut down"), "{e}");
                    failed += 1;
                }
            }
        }
        tx.send((served, failed)).unwrap();
    });
    let (served, _failed) = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("a ticket hung in wait() across shutdown");
    waiter.join().unwrap();
    // every executed request's ticket is in our list, so the served
    // waits must account for exactly the engine's completed count
    assert_eq!(
        served,
        engine.metrics().completed(),
        "served tickets must equal completed requests"
    );
}

/// `Ticket::wait_timeout`: a timed-out wait returns `Ok(None)` and leaves
/// the ticket fully resolvable — a later `wait()` still gets the result.
#[test]
fn wait_timeout_expires_then_the_ticket_still_resolves() {
    let gate = Arc::new(Mutex::new(()));
    let engine = Engine::builder()
        .serve_config(ServeConfig {
            max_batch: 2,
            batch_window: Duration::from_millis(1),
            queue_cap: 16,
            ..ServeConfig::default()
        })
        .model_desc(
            ModelDesc::builtin("mnist").unwrap(),
            BackendChoice::Custom(Arc::new(GatedBackend {
                gate: Arc::clone(&gate),
                inner: NullBackend {
                    input_len: 784,
                    n_classes: 10,
                },
            })),
        )
        .build()
        .unwrap();
    let held = gate.lock_or_recover();
    let mut x = vec![0.0f32; 784];
    x[3] = 1.0;
    let ticket = engine.submit("mnist", x).unwrap();
    // the backend is blocked: a short wait must time out, not hang
    let t0 = std::time::Instant::now();
    assert!(ticket
        .wait_timeout(Duration::from_millis(50))
        .unwrap()
        .is_none());
    assert!(t0.elapsed() >= Duration::from_millis(50));
    assert!(ticket.wait_timeout(Duration::from_millis(1)).unwrap().is_none());
    // release the backend: the SAME ticket resolves with its own logits
    drop(held);
    let c = ticket.wait().unwrap();
    assert_eq!(c.argmax, 3);
    // and an already-done ticket returns instantly regardless of timeout
    assert!(ticket
        .wait_timeout(Duration::from_millis(1))
        .unwrap()
        .is_some());
    engine.shutdown();
}
