//! PJRT runtime integration tests — the L3 <-> AOT bridge.
//!
//! These need `make artifacts` output; each test skips gracefully when the
//! artifacts are absent so `cargo test` stays green pre-build.  With
//! artifacts present they verify the full contract: HLO text loads and
//! compiles, SWT weights bind positionally, logits match across batch
//! sizes, and the Pallas-kernel VDU artifacts compute correct dot products.

use std::time::Duration;

use sonic::arch::SonicConfig;
use sonic::serve::{BackendChoice, Engine, InferenceBackend, ServeConfig};
use sonic::runtime::{load_manifest, PjrtBackend, Runtime};
use sonic::tensor::Tensor;
use sonic::util::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    if !cfg!(feature = "pjrt") {
        eprintln!("built without the `pjrt` feature; skipping PJRT test");
        return None;
    }
    let dir = sonic::artifacts_dir();
    if dir.join("manifest.json").is_file() {
        Some(dir)
    } else {
        eprintln!("artifacts not built; skipping PJRT test");
        None
    }
}

#[test]
fn manifest_lists_all_models_and_vdu_units() {
    let Some(dir) = artifacts() else { return };
    let m = load_manifest(&dir).unwrap();
    let keys: Vec<&str> = m.iter().map(|a| a.key.as_str()).collect();
    for want in ["mnist", "cifar10", "stl10", "svhn", "vdu_fc", "vdu_conv"] {
        assert!(keys.contains(&want), "missing {want} in manifest: {keys:?}");
    }
}

#[test]
fn vdu_fc_artifact_computes_quantized_matmul() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(3);
    let m = 50;
    let x = Tensor::new("x", vec![1, m], rng.normal_vec(m));
    let w = Tensor::new("w", vec![m, m], rng.normal_vec(m * m));
    let scale = Tensor::new("s", vec![m], vec![1.0; m]);
    let bias = Tensor::new("b", vec![m], vec![0.0; m]);
    let out = rt
        .run_raw("vdu_fc", &[x.clone(), w.clone(), scale, bias])
        .unwrap();
    assert_eq!(out.len(), m);
    // reference dot product; 16-bit DAC quantization error is tiny
    for j in 0..m {
        let want: f32 = (0..m).map(|k| x.data[k] * w.data[k * m + j]).sum();
        assert!(
            (out[j] - want).abs() < 1e-2 * want.abs().max(1.0),
            "col {j}: {} vs {want}",
            out[j]
        );
    }
}

#[test]
fn vdu_conv_artifact_shape_and_bn_scale() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(4);
    let (rows, k, n) = (128, 45, 64);
    let x = Tensor::new("x", vec![rows, k], rng.normal_vec(rows * k));
    let w = Tensor::new("w", vec![k, n], rng.normal_vec(k * n));
    let scale = Tensor::new("s", vec![n], vec![2.0; n]);
    let bias = Tensor::new("b", vec![n], vec![0.5; n]);
    let out = rt.run_raw("vdu_conv", &[x.clone(), w.clone(), scale, bias]).unwrap();
    assert_eq!(out.len(), rows * n);
    // spot-check one element with the BN scale applied
    let (i, j) = (17, 33);
    let want: f32 = (0..k).map(|kk| x.data[i * k + kk] * w.data[kk * n + j]).sum::<f32>()
        * 2.0
        + 0.5;
    let got = out[i * n + j];
    assert!((got - want).abs() < 1e-2 * want.abs().max(1.0), "{got} vs {want}");
}

#[test]
fn model_inference_deterministic_and_finite() {
    let Some(dir) = artifacts() else { return };
    let backend = PjrtBackend::load(&dir, "mnist").unwrap();
    let mut rng = Rng::new(5);
    let input = rng.normal_vec(backend.input_len());
    let a = backend.infer_batch(&[input.clone()]).unwrap();
    let b = backend.infer_batch(&[input]).unwrap();
    assert_eq!(a.len(), 1);
    assert_eq!(a[0].len(), 10);
    assert!(a[0].iter().all(|v| v.is_finite()));
    assert_eq!(a[0], b[0], "inference must be deterministic");
}

#[test]
fn batch8_path_matches_batch1_numerics() {
    let Some(dir) = artifacts() else { return };
    let backend = PjrtBackend::load(&dir, "mnist").unwrap();
    if backend.batch_size() < 8 {
        eprintln!("no batch-8 artifact; skipping");
        return;
    }
    let mut rng = Rng::new(6);
    let inputs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(backend.input_len())).collect();
    // 8 at once -> uses the _b8 artifact; one-at-a-time -> b1 path
    let batched = backend.infer_batch(&inputs).unwrap();
    for (i, x) in inputs.iter().enumerate() {
        let single = backend.infer_batch(std::slice::from_ref(x)).unwrap();
        for (a, b) in batched[i].iter().zip(&single[0]) {
            assert!(
                (a - b).abs() < 1e-3 * b.abs().max(1.0),
                "req {i}: batch {a} vs single {b}"
            );
        }
    }
}

#[test]
fn trained_model_beats_chance_on_synthetic_eval() {
    // The exported mnist model was trained on the deterministic synthetic
    // dataset; the PJRT path should classify fresh template+noise samples
    // far above 10% chance.  We regenerate eval samples with the same
    // template construction as python/compile/datasets.py is seeded by the
    // export — instead of reimplementing jax's PRNG, we check the weaker
    // but still meaningful property that logits differ across inputs and
    // the predicted class distribution is not degenerate.
    let Some(dir) = artifacts() else { return };
    let backend = PjrtBackend::load(&dir, "mnist").unwrap();
    let mut rng = Rng::new(7);
    let inputs: Vec<Vec<f32>> = (0..16).map(|_| rng.normal_vec(backend.input_len())).collect();
    let outs = backend.infer_batch(&inputs).unwrap();
    let mut classes = std::collections::BTreeSet::new();
    for o in &outs {
        classes.insert(sonic::serve::argmax(o));
    }
    // logits must vary across random inputs (weights actually loaded)
    assert!(
        outs.windows(2).any(|w| w[0] != w[1]),
        "identical logits for different inputs"
    );
    assert!(!classes.is_empty());
}

#[test]
fn engine_over_pjrt_serves_batches() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::builder()
        .arch(SonicConfig::paper_best())
        .artifacts_dir(&dir)
        .serve_config(ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            queue_cap: 64,
            ..ServeConfig::default()
        })
        .model("mnist", BackendChoice::Pjrt)
        .build()
        .unwrap();
    assert_eq!(engine.backend_kind("mnist").unwrap(), "pjrt");
    let per = engine.input_len("mnist").unwrap();
    let mut rng = Rng::new(8);
    let tickets: Vec<_> = (0..12)
        .map(|_| engine.submit("mnist", rng.normal_vec(per)).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    engine.shutdown();
    let m = engine.metrics();
    let metrics = &m.model("mnist").unwrap().serve;
    assert_eq!(metrics.completed, 12);
    assert!(metrics.photonic_fps() > 0.0);
    assert!(metrics.photonic_fps_per_watt() > 0.0);
}

#[test]
fn all_four_models_load_and_run() {
    let Some(dir) = artifacts() else { return };
    for name in ["mnist", "cifar10", "svhn", "stl10"] {
        let backend = match PjrtBackend::load(&dir, name) {
            Ok(b) => b,
            Err(e) => panic!("{name}: {e:#}"),
        };
        let mut rng = Rng::new(9);
        let out = backend
            .infer_batch(&[rng.normal_vec(backend.input_len())])
            .unwrap();
        assert_eq!(out[0].len(), 10, "{name}");
        assert!(out[0].iter().all(|v| v.is_finite()), "{name}");
    }
}
