"""Layer-wise, sparsity-aware magnitude pruning (SONIC §III.A).

Adapted from the gradual-pruning approach of Zhu & Gupta [11]: each layer
selected for pruning gets a binary mask of the same shape as its weight
tensor; weights are sorted by absolute value and the smallest are masked to
zero until the layer's target sparsity is reached.  Masks participate in the
forward pass during training (sparsity-aware training, not post-training
pruning), and the sparsity target ramps up on a cubic schedule.

Layer selection is layer-wise (not global) so sensitive layers — in these
models the first conv and the final classifier — can be protected, exactly
as the paper motivates.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from . import zoo


@dataclasses.dataclass(frozen=True)
class PrunePlan:
    """Which layers to prune and to what final sparsity.

    sparsity[i] applies to layer_names[i]; unlisted layers stay dense.
    """

    layer_names: tuple
    sparsity: tuple  # final fraction of zeros per listed layer

    def target_for(self, name: str) -> float:
        for n, s in zip(self.layer_names, self.sparsity):
            if n == name:
                return s
        return 0.0

    @property
    def n_layers_pruned(self) -> int:
        return len(self.layer_names)


def cubic_ramp(step: int, begin: int, end: int, final: float) -> float:
    """Zhu–Gupta cubic sparsity schedule: 0 -> final over [begin, end]."""
    if step <= begin:
        return 0.0
    if step >= end:
        return final
    t = (step - begin) / max(1, end - begin)
    return final * (1.0 - (1.0 - t) ** 3)


def magnitude_mask(w: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """Binary mask keeping the largest-|w| entries; zeros the smallest.

    Exactly floor(sparsity * size) entries are masked (ties broken by
    sort order), mirroring the paper's sort-and-mask description.
    """
    if sparsity <= 0.0:
        return jnp.ones_like(w)
    n = w.size
    k = int(sparsity * n)
    if k <= 0:
        return jnp.ones_like(w)
    if k >= n:
        return jnp.zeros_like(w)
    flat = jnp.abs(w).reshape(-1)
    # threshold = k-th smallest |w|; mask everything strictly below, then
    # drop ties deterministically until exactly k are masked.
    thresh = jnp.sort(flat)[k - 1]
    mask = (flat > thresh).astype(w.dtype)
    # Entries equal to the threshold: keep enough of them to hold exactly n-k.
    n_keep_needed = n - k - int(jnp.sum(flat > thresh))
    eq_idx = jnp.nonzero(flat == thresh, size=n, fill_value=-1)[0]
    keep_eq = jnp.where(
        (jnp.arange(n) < n_keep_needed) & (eq_idx >= 0), eq_idx, -1
    )
    mask = mask.at[keep_eq].set(
        jnp.where(keep_eq >= 0, 1.0, mask[keep_eq])
    )
    return mask.reshape(w.shape)


def default_plan(name: str, avg_sparsity: float | None = None) -> PrunePlan:
    """The per-model pruning plan used to reach Table 3 parameter counts.

    Layer choice follows the paper's Table 3 "layers pruned" counts; the
    per-layer sparsity levels were solved so that the surviving-parameter
    total matches Table 3 (see python/tests/test_sparsify.py).
    """
    spec = zoo.get(name)
    names = spec.layer_names()
    t3 = zoo.TABLE3[name]
    n_pruned = t3["layers_pruned"]
    # Prune the largest layers first (they dominate the parameter budget and
    # are least accuracy-sensitive), protect the first conv and final head
    # when the budget allows — the paper's layer-wise rationale.
    layers = [(n, p) for n, p in zip(names, _layer_sizes(spec))]
    protected = {names[0], names[-1]}
    candidates = sorted(
        (l for l in layers if l[0] not in protected),
        key=lambda t: -t[1],
    )
    if len(candidates) < n_pruned:  # need to dip into protected layers
        extra = [l for l in layers if l[0] in protected]
        candidates += sorted(extra, key=lambda t: -t[1])
    chosen = candidates[:n_pruned]
    chosen_names = [c[0] for c in chosen]

    # CONV layers prune to 50% so the dense per-slice kernel vectors hold
    # <= ceil(9 * 0.5) = 5 entries — the granularity behind the paper's
    # n = 5 finding (§V.B).  FC layers then absorb the remaining budget so
    # the surviving-parameter total matches Table 3.
    conv_s = 0.5
    total = spec.n_params
    target = t3["paper_params"]
    conv_names = {c.name for c in spec.convs}
    conv_pruned = sum(
        sz for n_, sz in zip(chosen_names, (c[1] for c in chosen))
        if n_ in conv_names
    ) * conv_s
    fc_prunable = sum(
        sz for n_, sz in zip(chosen_names, (c[1] for c in chosen))
        if n_ not in conv_names
    )
    budget = (total - target) - conv_pruned
    fc_s = min(max(budget / fc_prunable, 0.0), 0.95) if fc_prunable else 0.0
    sparsities = tuple(
        conv_s if n_ in conv_names else fc_s for n_ in chosen_names
    )
    return PrunePlan(tuple(chosen_names), sparsities)


def _layer_sizes(spec: zoo.ModelSpec) -> List[int]:
    return [c.n_params for c in spec.convs] + [f.n_params for f in spec.fcs]


def apply_masks(params: Dict[str, dict], masks: Dict[str, jnp.ndarray]):
    """Zero out masked weights: params[layer]['w'] *= mask."""
    out = {}
    for lname, p in params.items():
        if lname in masks:
            out[lname] = dict(p, w=p["w"] * masks[lname])
        else:
            out[lname] = p
    return out


def build_masks(
    params: Dict[str, dict], plan: PrunePlan, step: int, begin: int, end: int
) -> Dict[str, jnp.ndarray]:
    """Recompute masks at `step` of the cubic schedule."""
    masks = {}
    for lname in plan.layer_names:
        target = plan.target_for(lname)
        s = cubic_ramp(step, begin, end, target)
        masks[lname] = magnitude_mask(params[lname]["w"], s)
    return masks


def sparsity_report(params: Dict[str, dict]) -> Dict[str, float]:
    """Fraction of zero weights per layer (Fig. 7 'weight sparsity')."""
    rep = {}
    for lname, p in params.items():
        w = p["w"]
        rep[lname] = float(jnp.mean(w == 0.0))
    return rep


def surviving_params(params: Dict[str, dict]) -> int:
    """Total non-zero weights + all biases (Table 3 'No. of parameters')."""
    n = 0
    for p in params.values():
        n += int(jnp.sum(p["w"] != 0.0))
        n += int(p["b"].size)
    return n
