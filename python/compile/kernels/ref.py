"""Pure-jnp oracle for the L1 Pallas VDU kernels.

Every Pallas kernel in this package has a reference implementation here,
written only with `jnp` ops.  pytest (python/tests/test_kernel.py) asserts
allclose between kernel and oracle across a hypothesis-driven sweep of
shapes and dtypes — this is the core correctness signal for L1.

The photonic transfer chain being modelled (see DESIGN.md §1):

  activations --16-bit DAC--> VCSEL amplitudes  (quantize to 2^16 levels)
  weights     --6-bit DAC --> MR transmissions  (already clustered to <=64
                                                 centroids at build time;
                                                 the DAC step is exact)
  MR bank      : elementwise multiply
  broadband MR : per-output batch-norm scale
  photodetector: accumulate (sum) + bias
"""

from __future__ import annotations

import jax.numpy as jnp

# Activation DAC resolution (bits) used by SONIC for activations (Sec. V.A).
ACT_DAC_BITS = 16


def quantize_activations(x: jnp.ndarray, bits: int = ACT_DAC_BITS,
                         max_abs: float | None = None) -> jnp.ndarray:
    """Model the activation DAC: uniform quantization to 2^bits levels.

    The DAC has a fixed full-scale range; values are clipped to ±max_abs and
    snapped to the nearest of 2^bits uniformly spaced levels.  `max_abs`
    defaults to the per-call dynamic range (what SONIC's control unit would
    program per layer).
    """
    if max_abs is None:
        max_abs = jnp.max(jnp.abs(x)) + 1e-12
    levels = float(2 ** (bits - 1) - 1)
    step = max_abs / levels
    return jnp.clip(jnp.round(x / step), -levels, levels) * step


def vdu_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: jnp.ndarray | None = None,
    bias: jnp.ndarray | None = None,
    act_bits: int = ACT_DAC_BITS,
) -> jnp.ndarray:
    """Oracle for the photonic VDU matmul: (quantize(x) @ w) * scale + bias.

    x: [M, K] activations, w: [K, N] clustered weights,
    scale/bias: [N] broadband-MR batch-norm parameters (optional).
    """
    xq = quantize_activations(x, act_bits) if act_bits else x
    out = jnp.dot(xq, w, preferred_element_type=jnp.float32)
    if scale is not None:
        out = out * scale
    if bias is not None:
        out = out + bias
    return out


def im2col(x: jnp.ndarray, kh: int, kw: int) -> jnp.ndarray:
    """Unroll SAME-padded patches: [B,H,W,C] -> [B*H*W, kh*kw*C].

    This is the Fig. 2(a)->(b) unfurling: each output pixel's receptive
    field becomes one row of a dense matrix, turning convolution into the
    vector-dot-product operations SONIC's CONV VDUs consume.
    """
    b, h, w_, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(xp[:, i : i + h, j : j + w_, :])
    # [B,H,W,kh*kw*C] with channel fastest-varying, then kw, then kh
    patches = jnp.concatenate(cols, axis=-1)
    return patches.reshape(b * h * w_, kh * kw * c)


def vdu_conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: jnp.ndarray | None = None,
    bias: jnp.ndarray | None = None,
    act_bits: int = ACT_DAC_BITS,
) -> jnp.ndarray:
    """Oracle conv: im2col + VDU matmul.  x [B,H,W,Cin], w [kh,kw,Cin,Cout]."""
    b, h, w_, cin = x.shape
    kh, kw, _, cout = w.shape
    cols = im2col(x, kh, kw)  # [B*H*W, kh*kw*Cin]
    wmat = w.reshape(kh * kw * cin, cout)
    out = vdu_matmul(cols, wmat, scale, bias, act_bits)
    return out.reshape(b, h, w_, cout)


def maxpool2x2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 max pooling, stride 2 (electronic post-processing in SONIC)."""
    b, h, w_, c = x.shape
    x = x[:, : h - h % 2, : w_ - w_ % 2, :]
    x = x.reshape(b, h // 2, 2, w_ // 2, 2, c)
    return jnp.max(x, axis=(2, 4))
