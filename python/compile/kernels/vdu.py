"""L1: the SONIC vector-dot-product unit (VDU) as a Pallas kernel.

This is the paper's compute hot-spot — the photonic MR-bank multiply +
photodetector accumulate — re-expressed for a TPU-style memory hierarchy
(DESIGN.md §1 "Hardware adaptation"):

  * SONIC feeds *dense* vectors to VDUs after dataflow compression; here the
    BlockSpec tiles HBM->VMEM moves so every block the MXU sees is dense.
  * The VDU granularity (m=50 FC / n=5 CONV) maps to the tile shape; tiles
    are padded up to MXU-aligned blocks by the wrapper.
  * The activation DAC is modelled in-kernel (uniform 16-bit quantization,
    static per-call full-scale range — what SONIC's control unit programs).
  * The broadband batch-norm MR is the per-output-column `scale`; the
    photodetector is the K-accumulation; `bias` is the electronic partial-sum
    offset added at readout.
  * VCSEL power gating of residual zeros is numerically a no-op (0*w = 0),
    so the kernel keeps zeros in the multiply; the L3 simulator accounts the
    energy saving.

Kernels MUST run with interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.  Numerics are validated
against kernels/ref.py by pytest; TPU efficiency is *estimated* from the
BlockSpec (DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default block shape.  The M/N tiles stay MXU-lane-sized (128); K runs
# deep (2048) so most layers need no K-grid at all — each interpret-mode
# grid step costs a dynamic-slice/update round trip on CPU, and on TPU a
# deeper K tile raises arithmetic intensity at ~2.2 MiB VMEM per step
# (DESIGN.md §6; EXPERIMENTS.md §Perf L2 iteration 3).
BLOCK_M = 128
BLOCK_K = 2048
BLOCK_N = 512


def _vdu_kernel(x_ref, w_ref, scale_ref, bias_ref, qparams_ref, o_ref,
                *, n_k_blocks: int):
    """One (bm, bn) output tile; grid dim 2 walks the K blocks.

    qparams_ref holds (step, levels) for the activation DAC; step == 0
    disables quantization (used by tests to isolate the matmul path).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    step = qparams_ref[0]
    levels = qparams_ref[1]
    # Activation DAC: snap to the uniform grid. `where` keeps the un-quantized
    # path exact when step==0 (avoids 0/0).
    safe_step = jnp.where(step > 0, step, 1.0)
    xq = jnp.where(
        step > 0,
        jnp.clip(jnp.round(x / safe_step), -levels, levels) * safe_step,
        x,
    )
    acc = jnp.dot(xq, w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += acc

    # Broadband BN MR + electronic bias once the photodetector sum is complete.
    @pl.when(k == n_k_blocks - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] * scale_ref[...] + bias_ref[...]


def _pad_to(a: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(
    jax.jit,
    static_argnames=("act_bits", "block_m", "block_k", "block_n"),
)
def vdu_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: jnp.ndarray | None = None,
    bias: jnp.ndarray | None = None,
    act_bits: int = ref.ACT_DAC_BITS,
    block_m: int = BLOCK_M,
    block_k: int = BLOCK_K,
    block_n: int = BLOCK_N,
) -> jnp.ndarray:
    """Photonic VDU matmul: (DAC(x) @ w) * scale + bias, tiled via Pallas.

    x: [M, K] float32, w: [K, N] float32 (cluster-codebook values),
    scale/bias: [N] broadband-MR BN scale and electronic bias (default 1, 0).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    # Right-size tiles to the actual dims (8-aligned): FC layers at
    # batch<=8 would otherwise pad 1..8 rows up to 128 (128x wasted work),
    # and thin conv dims (K=9 for the first conv, N=32 outputs) pad 4-14x.
    # interpret=True has no MXU lane constraint, so snug blocks are pure
    # win on CPU; for a real-TPU build, re-lower with the 128-aligned
    # defaults (DESIGN.md §6).  (EXPERIMENTS.md §Perf, L2 iterations 1-2.)
    block_m = min(block_m, max(8, -(-m // 8) * 8))
    block_k = min(block_k, max(8, -(-k // 8) * 8))
    block_n = min(block_n, max(8, -(-n // 8) * 8))
    if scale is None:
        scale = jnp.ones((n,), jnp.float32)
    if bias is None:
        bias = jnp.zeros((n,), jnp.float32)

    # The DAC full-scale range is static per call (programmed per layer by
    # the control unit); computed outside the kernel like SONIC computes it
    # in the electronic domain before driving the VCSELs.
    if act_bits:
        levels = float(2 ** (act_bits - 1) - 1)
        step = (jnp.max(jnp.abs(x)) + 1e-12) / levels
        qparams = jnp.stack([step, jnp.asarray(levels, jnp.float32)])
    else:
        qparams = jnp.zeros((2,), jnp.float32)

    xp = _pad_to(x.astype(jnp.float32), block_m, block_k)
    wp = _pad_to(w.astype(jnp.float32), block_k, block_n)
    sp = _pad_to(scale.astype(jnp.float32).reshape(1, -1), 1, block_n)
    bp = _pad_to(bias.astype(jnp.float32).reshape(1, -1), 1, block_n)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // block_m, np_ // block_n, kp // block_k)

    out = pl.pallas_call(
        functools.partial(_vdu_kernel, n_k_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),  # qparams: tiny, whole-array
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, sp, bp, qparams)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("act_bits",))
def vdu_conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: jnp.ndarray | None = None,
    bias: jnp.ndarray | None = None,
    act_bits: int = ref.ACT_DAC_BITS,
) -> jnp.ndarray:
    """CONV layer through the VDU: Fig.2 im2col unroll, then the VDU matmul.

    x: [B,H,W,Cin], w: [kh,kw,Cin,Cout] (SAME padding, stride 1).
    The unroll happens in the electronic control unit (plain jnp here); only
    the dot products ride the photonic kernel, exactly as in the paper.
    """
    b, h, w_, cin = x.shape
    kh, kw, _, cout = w.shape
    cols = ref.im2col(x, kh, kw)
    wmat = w.reshape(kh * kw * cin, cout)
    out = vdu_matmul(cols, wmat, scale, bias, act_bits=act_bits)
    return out.reshape(b, h, w_, cout)
