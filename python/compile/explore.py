"""Fig. 6: sparsity × clustering × layers-pruned design-space exploration.

The paper sweeps (number of layers sparsified, average sparsity, number of
clusters) for the CIFAR10 model and picks the highest-accuracy point.  We
re-run the same sweep on the synthetic CIFAR10 stand-in.  Because full
retraining per point is too slow for a single-CPU build, the sweep reuses
one trained dense model and applies (mask, cluster) post-hoc per point, then
fine-tunes the evaluation through the masked forward — this preserves the
figure's *shape*: accuracy falls off with aggressive sparsity and very few
clusters, and the knee sits at moderate sparsity / 16+ clusters.

Emits artifacts/fig6_dse.json rows:
  {layers, sparsity, clusters, accuracy, surviving_params}
"""

from __future__ import annotations

import argparse
import itertools
import json
from pathlib import Path

import jax

from . import cluster, sparsify, train, zoo


def run_dse(
    name: str = "cifar10",
    layer_counts=(3, 5, 7),
    sparsities=(0.3, 0.5, 0.7),
    cluster_counts=(4, 16, 64),
    steps: int = 150,
    eval_batches: int = 2,
    log=print,
):
    # One dense-ish training run (light pruning so masks can be re-derived).
    cfg = train.TrainConfig(steps=steps, batch=32)
    base_plan = sparsify.PrunePlan((), ())
    params, _, _ = train.train(name, base_plan, cfg, log=log)

    spec = zoo.get(name)
    names = spec.layer_names()
    sizes = [c.n_params for c in spec.convs] + [f.n_params for f in spec.fcs]
    order = [n for n, _ in sorted(zip(names, sizes), key=lambda t: -t[1])]

    rows = []
    for nl, sp, cl in itertools.product(layer_counts, sparsities, cluster_counts):
        chosen = tuple(order[: min(nl, len(order))])
        plan = sparsify.PrunePlan(chosen, tuple(sp for _ in chosen))
        masks = {
            ln: sparsify.magnitude_mask(params[ln]["w"], sp) for ln in chosen
        }
        pruned = sparsify.apply_masks(params, masks)
        clustered, _ = cluster.cluster_params(pruned, cl)
        acc = train.evaluate(name, clustered, n_batches=eval_batches, batch=32)
        surv = sparsify.surviving_params(clustered)
        rows.append(
            dict(layers=nl, sparsity=sp, clusters=cl,
                 accuracy=acc, surviving_params=surv)
        )
        log(f"fig6: layers={nl} sparsity={sp} clusters={cl} acc={acc:.2f}%")
    best = max(rows, key=lambda r: r["accuracy"])
    return rows, best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    if args.quick:
        rows, best = run_dse(
            steps=30, layer_counts=(3, 7), sparsities=(0.3, 0.7),
            cluster_counts=(4, 16), eval_batches=1,
        )
    else:
        rows, best = run_dse()
    (outdir / "fig6_dse.json").write_text(
        json.dumps(dict(rows=rows, best=best), indent=1)
    )
    print(f"fig6_dse.json written; best = {best}")


if __name__ == "__main__":
    main()
