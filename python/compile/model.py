"""L2: JAX forward graphs for the four Table-1 CNNs.

Two equivalent forward paths:

  * `forward_train` / `use_kernel=False` — pure XLA ops (lax.conv); fast on
    CPU, used for training and as the whole-model oracle.
  * `forward_deploy(use_kernel=True)`  — every CONV/FC rides the L1 Pallas
    VDU kernel (im2col + photonic matmul with DAC quantization and
    broadband-MR batch-norm).  This is the graph `aot.py` lowers to HLO text
    for the Rust runtime.

Batch-norm: training uses batch statistics; for deployment the
(mean, var, gamma, beta) are folded into a per-channel (scale, bias) pair
applied by the broadband MR + electronic bias — `fold_bn`.

Parameters are a dict {layer_name: {'w', 'b', 'gamma', 'beta', 'mu', 'var'}}
so masks and clustering can address layers by name.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import zoo
from .kernels import ref, vdu


def init_params(name: str, key: jax.Array) -> Dict[str, dict]:
    """He-init parameters for a zoo model."""
    spec = zoo.get(name)
    params: Dict[str, dict] = {}
    for c in spec.convs:
        key, sub = jax.random.split(key)
        fan_in = c.kernel * c.kernel * c.in_ch
        w = jax.random.normal(sub, (c.kernel, c.kernel, c.in_ch, c.out_ch))
        w = w * jnp.sqrt(2.0 / fan_in)
        params[c.name] = dict(
            w=w.astype(jnp.float32),
            b=jnp.zeros((c.out_ch,), jnp.float32),
            gamma=jnp.ones((c.out_ch,), jnp.float32),
            beta=jnp.zeros((c.out_ch,), jnp.float32),
            mu=jnp.zeros((c.out_ch,), jnp.float32),
            var=jnp.ones((c.out_ch,), jnp.float32),
        )
    for f in spec.fcs:
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (f.in_dim, f.out_dim)) * jnp.sqrt(2.0 / f.in_dim)
        params[f.name] = dict(
            w=w.astype(jnp.float32), b=jnp.zeros((f.out_dim,), jnp.float32)
        )
    return params


def fold_bn(params: Dict[str, dict], eps: float = 1e-5) -> Dict[str, dict]:
    """Fold BN running stats into deploy-time (scale, bias) per conv layer.

    y = gamma * (conv(x)+b - mu)/sqrt(var+eps) + beta
      = conv(x) * scale + bias_eff   with scale = gamma/sqrt(var+eps).
    The broadband MR applies `scale`; the electronic readout adds `bias`.
    FC layers get scale=1, bias=b so all layers share one VDU signature.
    """
    out = {}
    for lname, p in params.items():
        if "gamma" in p:
            scale = p["gamma"] / jnp.sqrt(p["var"] + eps)
            bias = p["beta"] + (p["b"] - p["mu"]) * scale
            out[lname] = dict(w=p["w"], b=p["b"], scale=scale, bias=bias)
        else:
            out[lname] = dict(
                w=p["w"],
                b=p["b"],
                scale=jnp.ones((p["b"].shape[0],), jnp.float32),
                bias=p["b"],
            )
    return out


def _conv_xla(x, w):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def forward_train(
    name: str, params: Dict[str, dict], x: jnp.ndarray, bn_momentum: float = 0.9
) -> Tuple[jnp.ndarray, Dict[str, dict]]:
    """Training forward (pure XLA) with batch-norm batch statistics.

    Returns (logits, params-with-updated-running-stats).
    """
    spec = zoo.get(name)
    new_params = dict(params)
    for c in spec.convs:
        p = params[c.name]
        x = _conv_xla(x, p["w"]) + p["b"]
        mu = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        x = (x - mu) / jnp.sqrt(var + 1e-5) * p["gamma"] + p["beta"]
        new_params[c.name] = dict(
            p,
            mu=bn_momentum * p["mu"] + (1 - bn_momentum) * mu,
            var=bn_momentum * p["var"] + (1 - bn_momentum) * var,
        )
        x = jax.nn.relu(x)
        if c.pool:
            x = ref.maxpool2x2(x)
    x = x.reshape(x.shape[0], -1)
    for f in spec.fcs:
        p = params[f.name]
        x = x @ p["w"] + p["b"]
        if f.relu:
            x = jax.nn.relu(x)
    return x, new_params


def forward_deploy(
    name: str,
    folded: Dict[str, dict],
    x: jnp.ndarray,
    use_kernel: bool = True,
    act_bits: int = ref.ACT_DAC_BITS,
    collect_act_sparsity: bool = False,
):
    """Deployment forward on BN-folded params.

    use_kernel=True routes every matmul through the L1 Pallas VDU kernel —
    this is the graph AOT-lowered for the Rust runtime.  With
    collect_act_sparsity, also returns the per-layer fraction of zero input
    activations (Fig. 7's activation-sparsity series).
    """
    spec = zoo.get(name)
    act_sparsity: List[jnp.ndarray] = []
    mm = vdu.vdu_matmul if use_kernel else ref.vdu_matmul
    conv = vdu.vdu_conv2d if use_kernel else ref.vdu_conv2d
    for c in spec.convs:
        p = folded[c.name]
        if collect_act_sparsity:
            act_sparsity.append(jnp.mean(x == 0.0))
        x = conv(x, p["w"], p["scale"], p["bias"], act_bits=act_bits)
        x = jax.nn.relu(x)
        if c.pool:
            x = ref.maxpool2x2(x)
    x = x.reshape(x.shape[0], -1)
    for f in spec.fcs:
        p = folded[f.name]
        if collect_act_sparsity:
            act_sparsity.append(jnp.mean(x == 0.0))
        x = mm(x, p["w"], p["scale"], p["bias"], act_bits=act_bits)
        if f.relu:
            x = jax.nn.relu(x)
    if collect_act_sparsity:
        return x, jnp.stack(act_sparsity)
    return x


def accuracy(name: str, folded: Dict[str, dict], batches, use_kernel=False) -> float:
    """Top-1 accuracy over an iterable of (x, y) batches."""
    correct = total = 0
    for x, y in batches:
        logits = forward_deploy(name, folded, x, use_kernel=use_kernel)
        correct += int(jnp.sum(jnp.argmax(logits, axis=-1) == y))
        total += int(y.size)
    return 100.0 * correct / max(total, 1)


def flat_param_list(name: str, folded: Dict[str, dict]) -> List[Tuple[str, jnp.ndarray]]:
    """Deterministic (name, array) list: the AOT argument-order contract.

    Order: for each layer in spec order — w, b, scale, bias.  The Rust
    runtime feeds weight literals in exactly this order (tensor/swt.rs).
    """
    spec = zoo.get(name)
    out = []
    for lname in spec.layer_names():
        p = folded[lname]
        for field in ("w", "b", "scale", "bias"):
            out.append((f"{lname}.{field}", p[field]))
    return out
