"""Export deployed models for the Rust runtime.

Two files per model:

  artifacts/<name>.swt   — binary weight pack (read by rust/src/tensor/swt.rs)
  artifacts/<name>.json  — model descriptor: architecture, per-layer shapes,
                           sparsity stats, cluster codebook size, accuracy —
                           everything the L3 simulator needs that is *not*
                           derivable from the HLO.

SWT format (little-endian):
  magic  b"SWT1"
  u32    n_tensors
  per tensor:
    u32  name_len, name (utf-8)
    u8   dtype (0 = f32)
    u32  ndim
    u32  dims[ndim]
    f32  data[prod(dims)]   (row-major)

The tensor order is model.flat_param_list order — the same order the AOT'd
HLO expects its arguments, so Rust can feed literals positionally.
"""

from __future__ import annotations

import json
import struct
from pathlib import Path
from typing import Dict

import jax.numpy as jnp
import numpy as np

from . import cluster, model, sparsify, zoo

MAGIC = b"SWT1"


def write_swt(path: Path, tensors) -> None:
    """tensors: iterable of (name, array)."""
    tensors = list(tensors)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            a = np.asarray(arr, dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", 0))
            f.write(struct.pack("<I", a.ndim))
            for d in a.shape:
                f.write(struct.pack("<I", d))
            f.write(a.tobytes(order="C"))


def read_swt(path: Path):
    """Read back an SWT file (python-side round-trip check)."""
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        out = []
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            (dt,) = struct.unpack("<B", f.read(1))
            assert dt == 0
            (nd,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd))
            cnt = int(np.prod(dims)) if nd else 1
            data = np.frombuffer(f.read(4 * cnt), dtype="<f4").reshape(dims)
            out.append((name, data))
        return out


def descriptor(
    name: str,
    params: Dict[str, dict],
    n_clusters: int,
    accuracy: float,
    act_sparsity: Dict[str, float] | None = None,
) -> dict:
    """Build the JSON model descriptor consumed by the Rust simulator."""
    spec = zoo.get(name)
    wsp = sparsify.sparsity_report(params)
    uniq = cluster.unique_weights(params)
    layers = []
    hw = spec.input_hw
    for c in spec.convs:
        layers.append(
            dict(
                name=c.name,
                kind="conv",
                kernel=c.kernel,
                in_ch=c.in_ch,
                out_ch=c.out_ch,
                in_hw=hw,
                pool=c.pool,
                weight_sparsity=wsp[c.name],
                unique_weights=uniq[c.name],
                act_sparsity=(act_sparsity or {}).get(c.name, 0.0),
            )
        )
        if c.pool:
            hw //= 2
    for f in spec.fcs:
        layers.append(
            dict(
                name=f.name,
                kind="fc",
                in_dim=f.in_dim,
                out_dim=f.out_dim,
                relu=f.relu,
                weight_sparsity=wsp[f.name],
                unique_weights=uniq[f.name],
                act_sparsity=(act_sparsity or {}).get(f.name, 0.0),
            )
        )
    return dict(
        model=name,
        input_hw=spec.input_hw,
        input_ch=spec.input_ch,
        n_classes=spec.n_classes,
        total_params=spec.n_params,
        surviving_params=sparsify.surviving_params(params),
        n_clusters=n_clusters,
        weight_dac_bits=cluster.dac_bits_required(n_clusters),
        act_dac_bits=16,
        accuracy_synthetic=accuracy,
        paper=dict(
            baseline_params=spec.paper_params,
            baseline_accuracy=spec.paper_accuracy,
            table3=zoo.TABLE3[name],
        ),
        layers=layers,
    )


def export_model(
    outdir: Path,
    name: str,
    params: Dict[str, dict],
    n_clusters: int,
    accuracy: float,
    act_sparsity: Dict[str, float] | None = None,
) -> None:
    outdir.mkdir(parents=True, exist_ok=True)
    folded = model.fold_bn(params)
    write_swt(outdir / f"{name}.swt", model.flat_param_list(name, folded))
    desc = descriptor(name, params, n_clusters, accuracy, act_sparsity)
    (outdir / f"{name}.json").write_text(json.dumps(desc, indent=1))
