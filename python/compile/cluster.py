"""Post-training weight clustering with density-based centroid init (§III.B).

Following the Deep Compression recipe [12] the paper adapts: build the
cumulative distribution function of the (non-zero) weights, split it into C
equal-probability regions, initialize one centroid per region, then run 1-D
k-means until assignment converges.  The result is a model whose weights
take at most C unique non-zero values, so the weight DACs only need
ceil(log2 C) bits — the entire point of the exercise (6-bit DACs at 3 mW
versus 16-bit at 40 mW, Table 2).

Zero weights (pruned) are never clustered: sparsity survives clustering.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def density_centroids(w: jnp.ndarray, n_clusters: int) -> jnp.ndarray:
    """CDF-based (density) centroid initialization over non-zero weights.

    The empirical CDF is divided into n_clusters equal-mass regions; each
    centroid starts at its region's median weight value.
    """
    nz = w[w != 0.0]
    if nz.size == 0:
        return jnp.zeros((n_clusters,), w.dtype)
    s = jnp.sort(nz.reshape(-1))
    # region medians: quantiles at (i + 0.5)/C
    qs = (jnp.arange(n_clusters) + 0.5) / n_clusters
    idx = jnp.clip((qs * s.size).astype(jnp.int32), 0, s.size - 1)
    return s[idx]


def kmeans_1d(
    values: jnp.ndarray, centroids: jnp.ndarray, iters: int = 25
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """1-D k-means. Returns (final centroids, assignment of each value)."""

    def step(cents, _):
        d = jnp.abs(values[:, None] - cents[None, :])
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, cents.shape[0], dtype=values.dtype)
        counts = onehot.sum(axis=0)
        sums = (onehot * values[:, None]).sum(axis=0)
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), cents)
        return new, None

    cents, _ = jax.lax.scan(step, centroids, None, length=iters)
    d = jnp.abs(values[:, None] - cents[None, :])
    assign = jnp.argmin(d, axis=1)
    return cents, assign


def cluster_layer(w: jnp.ndarray, n_clusters: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cluster one layer's non-zero weights to n_clusters centroids.

    Returns (clustered weights — same shape, zeros preserved —, codebook).
    """
    flat = w.reshape(-1)
    nz_mask = flat != 0.0
    nz = flat[nz_mask]
    if nz.size == 0:
        return w, jnp.zeros((n_clusters,), w.dtype)
    cents = density_centroids(w, n_clusters)
    cents, assign = kmeans_1d(nz, cents)
    snapped = cents[assign]
    out = flat.at[jnp.nonzero(nz_mask, size=nz.size)[0]].set(snapped)
    return out.reshape(w.shape), cents


def cluster_params(
    params: Dict[str, dict], n_clusters: int
) -> Tuple[Dict[str, dict], Dict[str, jnp.ndarray]]:
    """Cluster every layer's weight tensor; biases stay full precision
    (they ride the electronic partial-sum path, not the MR DACs)."""
    out, books = {}, {}
    for lname, p in params.items():
        wq, book = cluster_layer(p["w"], n_clusters)
        out[lname] = dict(p, w=wq)
        books[lname] = book
    return out, books


def unique_weights(params: Dict[str, dict]) -> Dict[str, int]:
    """Number of distinct non-zero weight values per layer (DAC resolution
    check: must be <= the cluster count)."""
    rep = {}
    for lname, p in params.items():
        w = p["w"].reshape(-1)
        nz = w[w != 0.0]
        rep[lname] = int(jnp.unique(nz).size) if nz.size else 0
    return rep


def dac_bits_required(n_clusters: int) -> int:
    """DAC resolution for a C-cluster codebook: ceil(log2 C) bits."""
    bits = 0
    c = 1
    while c < n_clusters:
        c *= 2
        bits += 1
    return max(bits, 1)
