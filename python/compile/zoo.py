"""Model zoo: reconstructions of the four custom CNNs in SONIC Table 1.

The paper specifies the models only by dataset, conv/FC layer counts,
parameter totals, and baseline accuracy.  We reconstruct concrete
architectures that match the layer counts exactly and the parameter totals
to within a few parameters (see DESIGN.md §3):

  MNIST   : C112 - P - C32 - P - FC928 - FC10              = 1,498,730 (exact)
  CIFAR10 : C20 C20 P C38 C38 P C216 C216 P - FC10         =   552,870 (paper 552,874)
  STL10   : C80 C80 P C160 C160 P C232 C232 P - FC2291+head = 77,787,739 (paper 77,787,738)
  SVHN    : C56 C56 P C28 C28 P - FC272 - FC48 - FC10      =   552,362 (exact)

All convs are 3x3 / SAME, pools are 2x2 max.  Batch-norm follows every conv
(folded into a broadband-MR scale/bias at export; BN params are not counted,
matching the paper's weight+bias totals).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """A 3x3 SAME convolution layer, optionally followed by a 2x2 maxpool."""

    in_ch: int
    out_ch: int
    pool: bool = False
    kernel: int = 3

    @property
    def n_params(self) -> int:
        return self.kernel * self.kernel * self.in_ch * self.out_ch + self.out_ch

    @property
    def name(self) -> str:
        return f"conv{self.in_ch}x{self.out_ch}"


@dataclasses.dataclass(frozen=True)
class FcSpec:
    """A fully connected layer."""

    in_dim: int
    out_dim: int
    relu: bool = True

    @property
    def n_params(self) -> int:
        return self.in_dim * self.out_dim + self.out_dim

    @property
    def name(self) -> str:
        return f"fc{self.in_dim}x{self.out_dim}"


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A full CNN: conv stack then FC stack, on a square input."""

    name: str
    input_hw: int
    input_ch: int
    convs: Tuple[ConvSpec, ...]
    fcs: Tuple[FcSpec, ...]
    n_classes: int
    paper_params: int
    paper_accuracy: float  # Table 1 baseline accuracy (%)

    @property
    def n_params(self) -> int:
        return sum(c.n_params for c in self.convs) + sum(f.n_params for f in self.fcs)

    @property
    def n_conv_layers(self) -> int:
        return len(self.convs)

    @property
    def n_fc_layers(self) -> int:
        return len(self.fcs)

    @property
    def flat_dim(self) -> int:
        hw = self.input_hw
        for c in self.convs:
            if c.pool:
                hw //= 2
        return hw * hw * self.convs[-1].out_ch

    def layer_names(self) -> List[str]:
        return [c.name for c in self.convs] + [f.name for f in self.fcs]


def _mnist() -> ModelSpec:
    # 28x28x1; two pools -> 7x7.   Exact: 1,498,730.
    c1, c2, h = 112, 32, 928
    return ModelSpec(
        name="mnist",
        input_hw=28,
        input_ch=1,
        convs=(
            ConvSpec(1, c1, pool=True),
            ConvSpec(c1, c2, pool=True),
        ),
        fcs=(
            FcSpec(7 * 7 * c2, h),
            FcSpec(h, 10, relu=False),
        ),
        n_classes=10,
        paper_params=1_498_730,
        paper_accuracy=93.2,
    )


def _cifar10() -> ModelSpec:
    # 32x32x3; three pools -> 4x4.  552,870 vs paper 552,874 (Δ-4).
    c1, c2, c3 = 20, 38, 216
    return ModelSpec(
        name="cifar10",
        input_hw=32,
        input_ch=3,
        convs=(
            ConvSpec(3, c1),
            ConvSpec(c1, c1, pool=True),
            ConvSpec(c1, c2),
            ConvSpec(c2, c2, pool=True),
            ConvSpec(c2, c3),
            ConvSpec(c3, c3, pool=True),
        ),
        fcs=(FcSpec(4 * 4 * c3, 10, relu=False),),
        n_classes=10,
        paper_params=552_874,
        paper_accuracy=86.05,
    )


def _stl10() -> ModelSpec:
    # 96x96x3; three pools -> 12x12.  77,787,739 vs paper 77,787,738 (Δ+1).
    # The paper's "1 FC layer" cannot hold ~77M params ending at 10 classes;
    # we treat hidden-FC + 10-way head as the classifier block (DESIGN.md §3).
    c1, c2, c3, h = 80, 160, 232, 2291
    return ModelSpec(
        name="stl10",
        input_hw=96,
        input_ch=3,
        convs=(
            ConvSpec(3, c1),
            ConvSpec(c1, c1, pool=True),
            ConvSpec(c1, c2),
            ConvSpec(c2, c2, pool=True),
            ConvSpec(c2, c3),
            ConvSpec(c3, c3, pool=True),
        ),
        fcs=(
            FcSpec(12 * 12 * c3, h),
            FcSpec(h, 10, relu=False),
        ),
        n_classes=10,
        paper_params=77_787_738,
        paper_accuracy=74.6,
    )


def _svhn() -> ModelSpec:
    # 32x32x3; two pools -> 8x8.  Exact: 552,362.
    c1, c2 = 56, 28
    return ModelSpec(
        name="svhn",
        input_hw=32,
        input_ch=3,
        convs=(
            ConvSpec(3, c1),
            ConvSpec(c1, c1, pool=True),
            ConvSpec(c1, c2),
            ConvSpec(c2, c2, pool=True),
        ),
        fcs=(
            FcSpec(8 * 8 * c2, 272),
            FcSpec(272, 48),
            FcSpec(48, 10, relu=False),
        ),
        n_classes=10,
        paper_params=552_362,
        paper_accuracy=94.6,
    )


MODELS = {
    "mnist": _mnist(),
    "cifar10": _cifar10(),
    "stl10": _stl10(),
    "svhn": _svhn(),
}

# Per-model optimization recipe from Table 3: (#layers pruned, #clusters,
# paper-final params, paper-final accuracy).  Target sparsity per pruned
# layer is derived so that the remaining-parameter total matches Table 3.
TABLE3 = {
    "mnist": dict(layers_pruned=4, clusters=64, paper_params=749_365, paper_acc=92.89),
    "cifar10": dict(layers_pruned=7, clusters=16, paper_params=276_437, paper_acc=86.86),
    "stl10": dict(layers_pruned=5, clusters=64, paper_params=46_672_643, paper_acc=75.2),
    "svhn": dict(layers_pruned=5, clusters=64, paper_params=331_417, paper_acc=95.0),
}


def get(name: str) -> ModelSpec:
    try:
        return MODELS[name]
    except KeyError:
        raise KeyError(f"unknown model {name!r}; choose from {sorted(MODELS)}")


def verify_param_counts() -> List[str]:
    """Return a human-readable Table-1 reconstruction report."""
    rows = []
    for name, spec in MODELS.items():
        delta = spec.n_params - spec.paper_params
        rows.append(
            f"{name:8s} conv={spec.n_conv_layers} fc={spec.n_fc_layers} "
            f"params={spec.n_params:>11,d} paper={spec.paper_params:>11,d} "
            f"delta={delta:+d}"
        )
    return rows


if __name__ == "__main__":
    print("\n".join(verify_param_counts()))
