"""The full SONIC software pipeline (Table 3): train sparsity-aware, cluster,
measure activation sparsity, export — for all four models.

Also emits artifacts/table3.json (paper-vs-ours for Table 3) and
artifacts/fig7_sparsity.json (layer-wise weight + activation sparsity).

Invoked by `make artifacts` via aot.py, or standalone:
    cd python && python -m compile.optimize --out ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from . import cluster, datasets, export, model, sparsify, train, zoo

# Per-model training budgets tuned for a single-CPU build environment.
# STL10 is 77.8M params — keep it to a handful of steps; its role in the
# evaluation is structural (shapes + sparsity), see DESIGN.md §5.
BUDGETS = dict(
    mnist=dict(steps=220, batch=32),
    cifar10=dict(steps=200, batch=32),
    svhn=dict(steps=200, batch=32),
    # 77.8M params on one CPU core: few steps, small batch, gentle lr
    # (1e-3 diverges through the 33k-wide FC).
    stl10=dict(steps=12, batch=4, lr=1e-4),
)
QUICK_BUDGETS = dict(
    mnist=dict(steps=40, batch=16),
    cifar10=dict(steps=40, batch=16),
    svhn=dict(steps=40, batch=16),
    stl10=dict(steps=3, batch=2),
)


def measure_act_sparsity(name: str, params, n_batches=2, batch=8):
    """Per-layer input-activation zero fraction on the eval stream (Fig. 7)."""
    folded = model.fold_bn(params)
    spec = zoo.get(name)
    names = spec.layer_names()
    acc = jnp.zeros((len(names),))
    n = 0
    for x, y in datasets.eval_batches(name, n_batches, batch):
        _, sp = model.forward_deploy(
            name, folded, x, use_kernel=False, collect_act_sparsity=True
        )
        acc = acc + sp
        n += 1
    vals = acc / max(n, 1)
    return {ln: float(v) for ln, v in zip(names, vals)}


def optimize_model(name: str, outdir: Path, quick=False, log=print):
    budget = (QUICK_BUDGETS if quick else BUDGETS)[name]
    t3 = zoo.TABLE3[name]
    plan = sparsify.default_plan(name)
    log(f"[{name}] plan: prune {plan.n_layers_pruned} layers @ "
        f"{[round(s, 3) for s in plan.sparsity]}")
    cfg = train.TrainConfig(
        steps=budget["steps"],
        batch=budget["batch"],
        lr=budget.get("lr", 1e-3),
    )
    params, masks, history = train.train(name, plan, cfg, log=log)

    # Post-training weight clustering at the Table-3 cluster count.
    params, books = cluster.cluster_params(params, t3["clusters"])

    nb = 1 if name == "stl10" else 4
    bs = 2 if name == "stl10" else 32
    acc = train.evaluate(name, params, n_batches=nb, batch=bs)
    act_sp = measure_act_sparsity(
        name, params, n_batches=1, batch=2 if name == "stl10" else 8
    )
    export.export_model(outdir, name, params, t3["clusters"], acc, act_sp)
    surv = sparsify.surviving_params(params)
    log(f"[{name}] surviving={surv:,} (paper {t3['paper_params']:,}) "
        f"acc={acc:.2f}% loss {history[0]:.3f}->{history[-1]:.3f}")
    return dict(
        model=name,
        layers_pruned=plan.n_layers_pruned,
        clusters=t3["clusters"],
        surviving_params=surv,
        accuracy_synthetic=acc,
        loss_first=history[0],
        loss_last=history[-1],
        paper=t3,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training budgets (CI smoke)")
    ap.add_argument("--models", nargs="*", default=list(zoo.MODELS))
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    rows = []
    for name in args.models:
        rows.append(optimize_model(name, outdir, quick=args.quick))
    (outdir / "table3.json").write_text(json.dumps(rows, indent=1))

    # Fig. 7 data: layer-wise weight + activation sparsity per model.
    fig7 = {}
    for name in args.models:
        desc = json.loads((outdir / f"{name}.json").read_text())
        fig7[name] = [
            dict(
                layer=l["name"],
                weight_sparsity=l["weight_sparsity"],
                act_sparsity=l["act_sparsity"],
            )
            for l in desc["layers"]
        ]
    (outdir / "fig7_sparsity.json").write_text(json.dumps(fig7, indent=1))
    print("table3.json + fig7_sparsity.json written")


if __name__ == "__main__":
    main()
