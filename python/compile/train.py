"""Sparsity-aware training loop (§III.A): masked forward, L2 regularization,
cubic sparsity ramp, Adam.  Build-time only — never on the request path.

The loop is deliberately small-scale (single-CPU environment): a few hundred
steps on the synthetic datasets is enough for loss to fall well below chance
and accuracy to stabilize — the structural quantities SONIC's evaluation
needs (layer-wise weight/activation sparsity, cluster codebooks) are fully
exercised.  EXPERIMENTS.md reports these runs next to the paper's numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from . import datasets, model, sparsify, zoo


@dataclasses.dataclass
class TrainConfig:
    steps: int = 300
    batch: int = 32
    lr: float = 1e-3
    l2: float = 1e-4  # paper: L2 regularization during sparsity-aware training
    prune_begin_frac: float = 0.2  # cubic ramp start (fraction of steps)
    prune_end_frac: float = 0.8
    remask_every: int = 10
    seed: int = 0
    log_every: int = 25


def _loss_fn(name, params, masks, x, y, l2):
    masked = sparsify.apply_masks(params, masks)
    logits, new_params = model.forward_train(name, masked, x)
    ce = jnp.mean(
        -jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y]
    )
    reg = sum(jnp.sum(p["w"] ** 2) for p in masked.values())
    return ce + l2 * reg, (new_params, ce)


def _adam_update(g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**t)
    vh = v / (1 - b2**t)
    return lr * mh / (jnp.sqrt(vh) + eps), m, v


def train(
    name: str,
    plan: sparsify.PrunePlan | None = None,
    cfg: TrainConfig | None = None,
    log: Callable[[str], None] = print,
):
    """Train a zoo model with sparsity-aware masking.

    Returns (params, masks, loss_history).  params already has masks applied.
    """
    cfg = cfg or TrainConfig()
    plan = plan or sparsify.default_plan(name)
    key = jax.random.PRNGKey(cfg.seed)
    key, pk = jax.random.split(key)
    params = model.init_params(name, pk)
    masks = {ln: jnp.ones_like(params[ln]["w"]) for ln in plan.layer_names}
    trainable = ("w", "b", "gamma", "beta")

    opt_m = jax.tree.map(jnp.zeros_like, params)
    opt_v = jax.tree.map(jnp.zeros_like, params)

    grad_fn = jax.jit(
        jax.grad(
            lambda p, mk, x, y: _loss_fn(name, p, mk, x, y, cfg.l2),
            has_aux=True,
        ),
        static_argnames=(),
    )

    begin = int(cfg.steps * cfg.prune_begin_frac)
    end = int(cfg.steps * cfg.prune_end_frac)
    history: List[float] = []
    for step in range(1, cfg.steps + 1):
        key, bk = jax.random.split(key)
        x, y = datasets.make_batch(name, cfg.batch, bk)
        grads, (new_params, ce) = grad_fn(params, masks, x, y)
        history.append(float(ce))
        # Adam on trainable leaves; masked weights get zero grad via mask.
        for lname, p in params.items():
            for f in trainable:
                if f not in p:
                    continue
                g = grads[lname][f]
                if f == "w" and lname in masks:
                    g = g * masks[lname]
                upd, opt_m[lname][f], opt_v[lname][f] = _adam_update(
                    g, opt_m[lname][f], opt_v[lname][f], step, cfg.lr
                )
                p[f] = p[f] - upd
            # adopt BN running stats from the forward pass
            if "mu" in p:
                p["mu"] = new_params[lname]["mu"]
                p["var"] = new_params[lname]["var"]
        if step % cfg.remask_every == 0 or step == end:
            masks = sparsify.build_masks(params, plan, step, begin, end)
        if step % cfg.log_every == 0:
            log(f"[{name}] step {step:4d}/{cfg.steps} ce={float(ce):.4f}")

    params = sparsify.apply_masks(params, masks)
    return params, masks, history


def evaluate(name: str, params: Dict[str, dict], n_batches=8, batch=32,
             use_kernel=False) -> float:
    """Accuracy of (possibly sparsified/clustered) params on the eval stream."""
    folded = model.fold_bn(params)
    return model.accuracy(
        name, folded, datasets.eval_batches(name, n_batches, batch),
        use_kernel=use_kernel,
    )
