"""Deterministic synthetic stand-ins for MNIST / CIFAR10 / STL10 / SVHN.

The real datasets are not available offline; the accelerator evaluation
depends on model *shapes and sparsity structure*, not image semantics
(DESIGN.md §5).  Each class is a fixed low-frequency template; samples are
template + jitter + noise, so a CNN can genuinely learn the task (loss
decreases, accuracy well above chance) while staying fully reproducible.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import zoo


def _smooth(img: jnp.ndarray, iters: int = 2) -> jnp.ndarray:
    """Cheap separable box blur to create low-frequency class templates."""
    k = jnp.array([0.25, 0.5, 0.25])
    for _ in range(iters):
        img = jnp.apply_along_axis(lambda r: jnp.convolve(r, k, mode="same"), 0, img)
        img = jnp.apply_along_axis(lambda r: jnp.convolve(r, k, mode="same"), 1, img)
    return img


def class_templates(name: str, key: jax.Array | None = None) -> jnp.ndarray:
    """[n_classes, H, W, C] fixed templates for a model's dataset stand-in."""
    spec = zoo.get(name)
    if key is None:
        key = jax.random.PRNGKey(hash(name) % (2**31))
    hw, ch, nc = spec.input_hw, spec.input_ch, spec.n_classes
    keys = jax.random.split(key, nc * ch)
    temps = []
    for c in range(nc):
        chans = []
        for j in range(ch):
            raw = jax.random.normal(keys[c * ch + j], (hw, hw))
            chans.append(_smooth(raw, iters=3))
        temps.append(jnp.stack(chans, axis=-1))
    t = jnp.stack(temps)  # [nc, hw, hw, ch]
    # normalize each template to unit std for a consistent SNR
    t = t / (jnp.std(t, axis=(1, 2, 3), keepdims=True) + 1e-6)
    return t


def make_batch(
    name: str,
    n: int,
    key: jax.Array,
    noise: float = 0.6,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sample a batch: returns (images [n,H,W,C] float32, labels [n] int32)."""
    spec = zoo.get(name)
    temps = class_templates(name)
    k_lab, k_noise, k_shift = jax.random.split(key, 3)
    labels = jax.random.randint(k_lab, (n,), 0, spec.n_classes)
    base = temps[labels]
    # small random circular shifts emulate translation variance
    shifts = jax.random.randint(k_shift, (n, 2), -2, 3)

    def roll_one(img, sh):
        return jnp.roll(img, (sh[0], sh[1]), axis=(0, 1))

    base = jax.vmap(roll_one)(base, shifts)
    x = base + noise * jax.random.normal(k_noise, base.shape)
    return x.astype(jnp.float32), labels.astype(jnp.int32)


def eval_batches(name: str, n_batches: int, batch: int, seed: int = 1234):
    """Deterministic evaluation stream (generator of (x, y))."""
    key = jax.random.PRNGKey(seed)
    for i in range(n_batches):
        key, sub = jax.random.split(key)
        yield make_batch(name, batch, sub)
