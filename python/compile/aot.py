"""AOT bridge: lower the L2 deploy graphs (Pallas VDU kernels inside) to
HLO *text* artifacts for the Rust PJRT runtime.

HLO text — NOT `lowered.compile()` / serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
the `xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Per model we emit:
  artifacts/<name>.hlo.txt        batch-1 deploy forward, weights as ARGS
  artifacts/<name>_b8.hlo.txt     batch-8 variant (dynamic batcher fast path)
  artifacts/vdu_fc.hlo.txt        a bare m×m FC-VDU pass (50×50)
  artifacts/vdu_conv.hlo.txt      a bare n-granularity CONV-VDU pass (5-wide)
  artifacts/manifest.json         arg order + shapes per artifact

Weights stay *arguments* so STL10's 77.8M params live in <name>.swt, not in
HLO text.  Argument order == model.flat_param_list order, with the image
input first.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, zoo
from .kernels import vdu


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _deploy_fn(name: str, n_args: int):
    """Build fn(x, *flat_params) -> (logits,) with positional params."""
    spec = zoo.get(name)
    lnames = spec.layer_names()

    def fn(x, *flat):
        folded = {}
        for i, ln in enumerate(lnames):
            w, b, scale, bias = flat[4 * i : 4 * i + 4]
            folded[ln] = dict(w=w, b=b, scale=scale, bias=bias)
        logits = model.forward_deploy(name, folded, x, use_kernel=True)
        return (logits,)

    return fn


def lower_model(name: str, batch: int) -> tuple[str, list]:
    """Lower one model at a given batch size; returns (hlo_text, arg_specs)."""
    spec = zoo.get(name)
    key = jax.random.PRNGKey(0)
    params = model.init_params(name, key)
    folded = model.fold_bn(params)
    flat = model.flat_param_list(name, folded)
    arg_specs = [
        dict(name="input", shape=[batch, spec.input_hw, spec.input_hw, spec.input_ch])
    ] + [dict(name=n, shape=list(a.shape)) for n, a in flat]

    x_spec = jax.ShapeDtypeStruct(
        (batch, spec.input_hw, spec.input_hw, spec.input_ch), jnp.float32
    )
    flat_specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for _, a in flat]
    fn = _deploy_fn(name, len(flat_specs))
    # keep_unused: the deploy graph consumes the BN-folded (scale, bias)
    # and never reads the raw per-layer `b`, but the artifact's positional
    # argument contract (== SWT tensor order) must keep every slot.
    lowered = jax.jit(fn, keep_unused=True).lower(x_spec, *flat_specs)
    return to_hlo_text(lowered), arg_specs


def lower_vdu_units() -> dict:
    """Bare VDU passes at the paper's best config granularity (n=5, m=50).

    fc:   [1,50] x [50,50] -> [1,50]   (one m×m FC-VDU pass)
    conv: [128,45] x [45,64] -> [128,64] (a batched n=5 im2col tile:
          45 = 3x3 kernel on 5 channels, batched 128 patches — the MXU-shape
          recovery described in DESIGN.md §6)
    """
    out = {}

    def fc(x, w, s, b):
        return (vdu.vdu_matmul(x, w, s, b),)

    m = 50
    specs = [
        jax.ShapeDtypeStruct((1, m), jnp.float32),
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
    ]
    out["vdu_fc"] = (
        to_hlo_text(jax.jit(fc).lower(*specs)),
        [dict(name=n, shape=list(s.shape)) for n, s in
         zip(["x", "w", "scale", "bias"], specs)],
    )

    specs = [
        jax.ShapeDtypeStruct((128, 45), jnp.float32),
        jax.ShapeDtypeStruct((45, 64), jnp.float32),
        jax.ShapeDtypeStruct((64,), jnp.float32),
        jax.ShapeDtypeStruct((64,), jnp.float32),
    ]
    out["vdu_conv"] = (
        to_hlo_text(jax.jit(fc).lower(*specs)),
        [dict(name=n, shape=list(s.shape)) for n, s in
         zip(["x", "w", "scale", "bias"], specs)],
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="kept for Makefile compat; parent dir is used")
    ap.add_argument("--models", nargs="*", default=list(zoo.MODELS))
    ap.add_argument("--batches", nargs="*", type=int, default=[1, 8])
    args = ap.parse_args()
    outdir = Path(args.out).parent
    outdir.mkdir(parents=True, exist_ok=True)

    manifest = {}
    for name in args.models:
        for batch in args.batches:
            suffix = "" if batch == 1 else f"_b{batch}"
            fname = f"{name}{suffix}.hlo.txt"
            print(f"lowering {name} batch={batch} ...", flush=True)
            text, arg_specs = lower_model(name, batch)
            (outdir / fname).write_text(text)
            manifest[f"{name}{suffix}"] = dict(
                file=fname, batch=batch, args=arg_specs
            )
            print(f"  wrote {fname} ({len(text):,} chars)")

    for key, (text, arg_specs) in lower_vdu_units().items():
        (outdir / f"{key}.hlo.txt").write_text(text)
        manifest[key] = dict(file=f"{key}.hlo.txt", batch=1, args=arg_specs)
        print(f"  wrote {key}.hlo.txt ({len(text):,} chars)")

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    # Makefile compat sentinel: model.hlo.txt = the MNIST b1 artifact.
    sentinel = outdir / "model.hlo.txt"
    sentinel.write_text((outdir / "mnist.hlo.txt").read_text())
    print(f"manifest.json written ({len(manifest)} artifacts)")

    print("\nTable 1 reconstruction check:")
    for row in zoo.verify_param_counts():
        print(" ", row)


if __name__ == "__main__":
    main()
