"""L1 correctness: Pallas VDU kernel vs pure-jnp oracle (ref.py).

This is the CORE correctness signal for Layer 1.  hypothesis sweeps shapes;
fixed tests pin the photonic-chain semantics (DAC quantization, broadband-MR
scale, bias, padding edges).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, vdu


def rnd(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestVduMatmulVsRef:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 80),
        k=st.integers(1, 70),
        n=st.integers(1, 60),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, m, k, n, seed):
        x = rnd(seed, (m, k))
        w = rnd(seed + 1, (k, n))
        s = rnd(seed + 2, (n,))
        b = rnd(seed + 3, (n,))
        got = vdu.vdu_matmul(x, w, s, b)
        want = ref.vdu_matmul(x, w, s, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_no_quantization_path(self):
        x, w = rnd(0, (33, 65)), rnd(1, (65, 17))
        got = vdu.vdu_matmul(x, w, act_bits=0)
        want = jnp.dot(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_defaults_scale_one_bias_zero(self):
        x, w = rnd(2, (8, 8)), rnd(3, (8, 8))
        got = vdu.vdu_matmul(x, w)
        want = ref.vdu_matmul(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_exact_block_multiple(self):
        # M, K, N exactly at block boundaries: no padding path.
        x, w = rnd(4, (128, 128)), rnd(5, (128, 128))
        got = vdu.vdu_matmul(x, w, act_bits=0)
        np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)

    def test_multi_k_block_accumulation(self):
        # K > block_k exercises the k-grid accumulation + epilogue-once.
        x, w = rnd(6, (16, 300)), rnd(7, (300, 16))
        s, b = rnd(8, (16,)), rnd(9, (16,))
        got = vdu.vdu_matmul(x, w, s, b, act_bits=0)
        want = (x @ w) * s + b
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("bm,bk,bn", [(32, 32, 32), (64, 128, 32), (128, 64, 128)])
    def test_block_shape_sweep(self, bm, bk, bn):
        x, w = rnd(10, (70, 90)), rnd(11, (90, 40))
        got = vdu.vdu_matmul(x, w, act_bits=0, block_m=bm, block_k=bk, block_n=bn)
        np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)

    def test_single_element(self):
        x = jnp.array([[2.0]])
        w = jnp.array([[3.0]])
        got = vdu.vdu_matmul(x, w, act_bits=0)
        np.testing.assert_allclose(got, [[6.0]], rtol=1e-6)

    def test_zero_inputs_power_gated_rows(self):
        # Residual sparsity: zero activations must produce exact zeros
        # (the power-gated VCSEL contributes nothing to the photodetector).
        x = jnp.zeros((4, 32))
        w = rnd(12, (32, 8))
        got = vdu.vdu_matmul(x, w)
        np.testing.assert_array_equal(np.asarray(got), np.zeros((4, 8)))


class TestQuantization:
    def test_quantize_idempotent(self):
        x = rnd(20, (64, 64))
        q1 = ref.quantize_activations(x, 8)
        # re-quantizing with the same static range must be a fixed point
        q2 = ref.quantize_activations(q1, 8, max_abs=float(jnp.max(jnp.abs(x))) + 1e-12)
        np.testing.assert_allclose(q1, q2, rtol=0, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(bits=st.integers(2, 16), seed=st.integers(0, 1000))
    def test_quantization_error_bound(self, bits, seed):
        x = rnd(seed, (32, 32))
        q = ref.quantize_activations(x, bits)
        step = float(jnp.max(jnp.abs(x)) + 1e-12) / (2 ** (bits - 1) - 1)
        assert float(jnp.max(jnp.abs(q - x))) <= step / 2 + 1e-6

    def test_16bit_negligible_error(self):
        x = rnd(21, (16, 16))
        q = ref.quantize_activations(x, 16)
        assert float(jnp.max(jnp.abs(q - x))) < 1e-3

    def test_levels_count(self):
        # 3-bit DAC -> at most 2^3 distinct values on a symmetric ramp
        x = jnp.linspace(-1, 1, 1000).reshape(10, 100)
        q = ref.quantize_activations(x, 3)
        assert len(np.unique(np.asarray(q))) <= 2**3


class TestVduConv2dVsRef:
    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 3),
        hw=st.integers(3, 12),
        cin=st.integers(1, 6),
        cout=st.integers(1, 8),
        seed=st.integers(0, 1000),
    )
    def test_hypothesis_conv(self, b, hw, cin, cout, seed):
        x = rnd(seed, (b, hw, hw, cin))
        w = rnd(seed + 1, (3, 3, cin, cout))
        got = vdu.vdu_conv2d(x, w)
        want = ref.vdu_conv2d(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_conv_matches_lax_conv(self):
        # im2col + matmul must equal XLA's native convolution.
        x = rnd(30, (2, 8, 8, 4))
        w = rnd(31, (3, 3, 4, 6))
        got = ref.vdu_conv2d(x, w, act_bits=0)
        want = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_conv_with_bn_scale(self):
        x = rnd(32, (1, 5, 5, 3))
        w = rnd(33, (3, 3, 3, 4))
        s, b = rnd(34, (4,)), rnd(35, (4,))
        got = vdu.vdu_conv2d(x, w, s, b, act_bits=0)
        want = ref.vdu_conv2d(x, w, s, b, act_bits=0)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestIm2col:
    def test_shape(self):
        x = rnd(40, (2, 7, 9, 3))
        cols = ref.im2col(x, 3, 3)
        assert cols.shape == (2 * 7 * 9, 27)

    def test_center_pixel_identity(self):
        # 1x1 "kernel" unroll is the identity flatten.
        x = rnd(41, (1, 4, 4, 2))
        cols = ref.im2col(x, 1, 1)
        np.testing.assert_allclose(cols, x.reshape(16, 2))

    def test_padding_zeros_at_border(self):
        x = jnp.ones((1, 3, 3, 1))
        cols = ref.im2col(x, 3, 3)
        # corner output pixel sees 4 in-bounds ones and 5 padded zeros
        corner = np.asarray(cols[0])
        assert corner.sum() == 4.0


class TestMaxpool:
    def test_basic(self):
        x = jnp.arange(16.0).reshape(1, 4, 4, 1)
        p = ref.maxpool2x2(x)
        np.testing.assert_allclose(
            np.asarray(p)[0, :, :, 0], [[5.0, 7.0], [13.0, 15.0]]
        )

    def test_odd_dim_truncates(self):
        x = rnd(50, (1, 5, 5, 2))
        p = ref.maxpool2x2(x)
        assert p.shape == (1, 2, 2, 2)
