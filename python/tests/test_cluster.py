"""§III.B weight clustering: codebook size, zero preservation, DAC bits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import cluster, model, sparsify


def rnd(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


class TestDensityCentroids:
    def test_count(self):
        w = rnd(0, (1000,))
        c = cluster.density_centroids(w, 16)
        assert c.shape == (16,)

    def test_centroids_within_range(self):
        w = rnd(1, (500,))
        c = cluster.density_centroids(w, 8)
        assert float(jnp.min(c)) >= float(jnp.min(w))
        assert float(jnp.max(c)) <= float(jnp.max(w))

    def test_equal_mass_regions(self):
        # For a uniform distribution, centroids should be ~evenly spaced.
        w = jnp.linspace(-1, 1, 10001)
        c = np.asarray(cluster.density_centroids(w, 10))
        gaps = np.diff(np.sort(c))
        assert gaps.std() / gaps.mean() < 0.05

    def test_ignores_zeros(self):
        # Density init must be built on *non-zero* weights only.
        w = jnp.concatenate([jnp.zeros(900), jnp.linspace(1.0, 2.0, 100)])
        c = np.asarray(cluster.density_centroids(w, 4))
        assert (c >= 1.0).all()


class TestClusterLayer:
    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(50, 400),
        n_clusters=st.sampled_from([4, 16, 64]),
        seed=st.integers(0, 10**6),
    )
    def test_unique_values_bounded(self, n, n_clusters, seed):
        w = rnd(seed, (n,))
        wq, book = cluster.cluster_layer(w, n_clusters)
        uniq = np.unique(np.asarray(wq[wq != 0])).size
        assert uniq <= n_clusters

    def test_zeros_preserved(self):
        w = rnd(2, (20, 20))
        mask = sparsify.magnitude_mask(w, 0.6)
        ws = w * mask
        wq, _ = cluster.cluster_layer(ws, 16)
        np.testing.assert_array_equal(np.asarray(wq == 0), np.asarray(ws == 0))

    def test_shape_preserved(self):
        w = rnd(3, (3, 3, 4, 8))
        wq, _ = cluster.cluster_layer(w, 8)
        assert wq.shape == w.shape

    def test_snap_error_small(self):
        # with 64 clusters the mean quantization error is small vs weight std
        # (max error sits in the distribution tails where regions are wide)
        w = rnd(4, (2000,))
        wq, _ = cluster.cluster_layer(w, 64)
        err = float(jnp.mean(jnp.abs(wq - w)))
        assert err < 0.05 * float(jnp.std(w))

    def test_all_zero_layer(self):
        w = jnp.zeros((10, 10))
        wq, book = cluster.cluster_layer(w, 16)
        np.testing.assert_array_equal(np.asarray(wq), 0.0)


class TestClusterParams:
    def test_model_end_to_end(self):
        params = model.init_params("svhn", jax.random.PRNGKey(0))
        clustered, books = cluster.cluster_params(params, 16)
        uniq = cluster.unique_weights(clustered)
        assert all(v <= 16 for v in uniq.values())
        # biases untouched (electronic path)
        for ln in params:
            np.testing.assert_array_equal(
                np.asarray(params[ln]["b"]), np.asarray(clustered[ln]["b"])
            )


class TestDacBits:
    @pytest.mark.parametrize(
        "c,bits", [(2, 1), (4, 2), (16, 4), (64, 6), (17, 5), (64, 6), (3, 2)]
    )
    def test_bits(self, c, bits):
        assert cluster.dac_bits_required(c) == bits

    def test_table3_clusters_fit_6bit(self):
        # the paper's conclusion: max 64 clusters across models -> 6-bit DACs
        from compile import zoo

        for t3 in zoo.TABLE3.values():
            assert cluster.dac_bits_required(t3["clusters"]) <= 6
