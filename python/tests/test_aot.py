"""AOT lowering: HLO-text interchange contract (shape, args, parseability)."""

import json

import pytest

from compile import aot, zoo


@pytest.fixture(scope="module")
def vdu_units():
    return aot.lower_vdu_units()


class TestVduUnitLowering:
    def test_both_units_present(self, vdu_units):
        assert set(vdu_units) == {"vdu_fc", "vdu_conv"}

    def test_hlo_text_structure(self, vdu_units):
        text, specs = vdu_units["vdu_fc"]
        # HLO text, not proto bytes: module header + ENTRY computation
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # 4 args: x, w, scale, bias
        assert len(specs) == 4
        assert specs[0]["shape"] == [1, 50]
        assert specs[1]["shape"] == [50, 50]

    def test_conv_unit_mxu_shape(self, vdu_units):
        _, specs = vdu_units["vdu_conv"]
        # batched n=5 granularity: 128 patches x (3*3*5)
        assert specs[0]["shape"] == [128, 45]

    def test_no_custom_calls(self, vdu_units):
        """interpret=True must lower to plain HLO (no Mosaic custom-call),
        otherwise the Rust CPU PJRT client cannot execute the artifact."""
        for text, _ in vdu_units.values():
            assert "custom-call" not in text or "Mosaic" not in text


class TestModelLowering:
    def test_mnist_lowering(self):
        text, specs = aot.lower_model("mnist", 1)
        assert text.startswith("HloModule")
        # input + 4 tensors per layer
        spec = zoo.get("mnist")
        n_layers = spec.n_conv_layers + spec.n_fc_layers
        assert len(specs) == 1 + 4 * n_layers
        assert specs[0]["shape"] == [1, 28, 28, 1]
        # weights are ARGUMENTS: HLO text stays small (no 1.5M-param consts)
        assert len(text) < 2_000_000

    def test_arg_order_contract(self):
        _, specs = aot.lower_model("svhn", 2)
        names = [s["name"] for s in specs]
        assert names[0] == "input"
        assert names[1] == "conv3x56.w"
        assert names[2] == "conv3x56.b"
        assert names[3] == "conv3x56.scale"
        assert names[4] == "conv3x56.bias"
        assert specs[0]["shape"][0] == 2  # batch honoured
