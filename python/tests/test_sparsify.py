"""§III.A sparsification: mask exactness, cubic schedule, Table-3 plans."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, sparsify, zoo


class TestMagnitudeMask:
    @settings(max_examples=30, deadline=None)
    @given(
        n=st.integers(10, 500),
        sparsity=st.floats(0.0, 0.95),
        seed=st.integers(0, 10**6),
    )
    def test_exact_count(self, n, sparsity, seed):
        w = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        mask = sparsify.magnitude_mask(w, sparsity)
        k = int(sparsity * n)
        assert int(jnp.sum(mask == 0)) == k

    def test_keeps_largest(self):
        w = jnp.array([0.1, -5.0, 0.01, 3.0, -0.2])
        mask = sparsify.magnitude_mask(w, 0.4)  # mask 2 smallest: 0.01, 0.1
        np.testing.assert_array_equal(np.asarray(mask), [0, 1, 0, 1, 1])

    def test_zero_sparsity_all_ones(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (7, 7))
        mask = sparsify.magnitude_mask(w, 0.0)
        assert float(jnp.sum(mask)) == 49.0

    def test_ties_deterministic(self):
        # all-equal magnitudes: still exactly k masked
        w = jnp.ones((100,))
        mask = sparsify.magnitude_mask(w, 0.5)
        assert int(jnp.sum(mask == 0)) == 50

    def test_shape_preserved(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 8))
        mask = sparsify.magnitude_mask(w, 0.3)
        assert mask.shape == w.shape


class TestCubicRamp:
    def test_boundaries(self):
        assert sparsify.cubic_ramp(0, 10, 90, 0.8) == 0.0
        assert sparsify.cubic_ramp(90, 10, 90, 0.8) == 0.8
        assert sparsify.cubic_ramp(1000, 10, 90, 0.8) == 0.8

    def test_monotone(self):
        vals = [sparsify.cubic_ramp(s, 0, 100, 0.7) for s in range(0, 101, 5)]
        assert all(b >= a for a, b in zip(vals, vals[1:]))

    def test_fast_early_slow_late(self):
        # cubic: more than half the final sparsity is reached by midpoint
        mid = sparsify.cubic_ramp(50, 0, 100, 1.0)
        assert mid > 0.5


class TestDefaultPlans:
    @pytest.mark.parametrize("name", list(zoo.MODELS))
    def test_plan_matches_table3_layer_count(self, name):
        plan = sparsify.default_plan(name)
        assert plan.n_layers_pruned == zoo.TABLE3[name]["layers_pruned"]

    @pytest.mark.parametrize("name", ["mnist", "cifar10", "svhn"])
    def test_plan_reaches_table3_params(self, name):
        """Masking at the plan's targets lands near Table 3's param count."""
        spec = zoo.get(name)
        params = model.init_params(name, jax.random.PRNGKey(0))
        plan = sparsify.default_plan(name)
        masks = {
            ln: sparsify.magnitude_mask(params[ln]["w"], plan.target_for(ln))
            for ln in plan.layer_names
        }
        pruned = sparsify.apply_masks(params, masks)
        surv = sparsify.surviving_params(pruned)
        target = zoo.TABLE3[name]["paper_params"]
        assert abs(surv - target) / target < 0.01, (surv, target)

    def test_sparsity_bounded(self):
        for name in zoo.MODELS:
            plan = sparsify.default_plan(name)
            assert all(0.0 <= s <= 0.95 for s in plan.sparsity)

    @pytest.mark.parametrize("name", list(zoo.MODELS))
    def test_conv_layers_pinned_at_half(self, name):
        """Pruned conv layers use 50% sparsity so dense per-slice kernel
        vectors hold <= 5 entries — the basis of the paper's n=5 finding."""
        plan = sparsify.default_plan(name)
        conv_names = {c.name for c in zoo.get(name).convs}
        for ln, s in zip(plan.layer_names, plan.sparsity):
            if ln in conv_names:
                assert s == 0.5, (ln, s)

    @pytest.mark.parametrize("name", list(zoo.MODELS))
    def test_dense_kernel_vector_granularity(self, name):
        """ceil(9 * (1 - s_conv)) <= 5 for every pruned conv layer."""
        import math

        plan = sparsify.default_plan(name)
        conv_names = {c.name for c in zoo.get(name).convs}
        for ln, s in zip(plan.layer_names, plan.sparsity):
            if ln in conv_names:
                assert math.ceil(9 * (1 - s)) <= 5


class TestApplyAndReport:
    def test_apply_masks_zeroes(self):
        params = model.init_params("mnist", jax.random.PRNGKey(0))
        mask = jnp.zeros_like(params["fc1568x928"]["w"])
        out = sparsify.apply_masks(params, {"fc1568x928": mask})
        assert float(jnp.sum(out["fc1568x928"]["w"] != 0)) == 0
        # untouched layers intact
        assert float(jnp.sum(out["conv1x112"]["w"] != 0)) > 0

    def test_sparsity_report(self):
        params = model.init_params("svhn", jax.random.PRNGKey(1))
        rep = sparsify.sparsity_report(params)
        assert set(rep) == set(zoo.get("svhn").layer_names())
        assert all(v < 0.01 for v in rep.values())  # dense init

    def test_surviving_params_dense_equals_total(self):
        name = "cifar10"
        params = model.init_params(name, jax.random.PRNGKey(2))
        surv = sparsify.surviving_params(params)
        # He-init weights are almost surely nonzero
        assert surv == zoo.get(name).n_params
