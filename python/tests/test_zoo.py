"""Table 1 reconstruction: layer counts exact, param totals within tolerance."""

import pytest

from compile import zoo

# (model, conv layers, fc layers) straight from Table 1
TABLE1 = {
    "mnist": (2, 2, 1_498_730),
    "cifar10": (6, 1, 552_874),
    "stl10": (6, 2, 77_787_738),  # see DESIGN.md §3: hidden FC + head
    "svhn": (4, 3, 552_362),
}


class TestTable1:
    @pytest.mark.parametrize("name", list(zoo.MODELS))
    def test_conv_layer_count(self, name):
        assert zoo.get(name).n_conv_layers == TABLE1[name][0]

    @pytest.mark.parametrize("name", ["mnist", "cifar10", "svhn"])
    def test_fc_layer_count(self, name):
        assert zoo.get(name).n_fc_layers == TABLE1[name][1]

    @pytest.mark.parametrize("name,maxdelta", [
        ("mnist", 0), ("svhn", 0), ("cifar10", 4), ("stl10", 1),
    ])
    def test_param_totals(self, name, maxdelta):
        spec = zoo.get(name)
        assert abs(spec.n_params - spec.paper_params) <= maxdelta

    @pytest.mark.parametrize("name", list(zoo.MODELS))
    def test_spec_consistency(self, name):
        """conv chaining and FC input dims line up with pooling."""
        spec = zoo.get(name)
        ch = spec.input_ch
        for c in spec.convs:
            assert c.in_ch == ch
            ch = c.out_ch
        assert spec.fcs[0].in_dim == spec.flat_dim
        for a, b in zip(spec.fcs, spec.fcs[1:]):
            assert b.in_dim == a.out_dim
        assert spec.fcs[-1].out_dim == spec.n_classes


class TestTable3Meta:
    def test_all_models_present(self):
        assert set(zoo.TABLE3) == set(zoo.MODELS)

    def test_cluster_counts_match_paper(self):
        assert zoo.TABLE3["cifar10"]["clusters"] == 16
        for name in ("mnist", "stl10", "svhn"):
            assert zoo.TABLE3[name]["clusters"] == 64

    def test_pruned_param_fraction_sane(self):
        for name, t3 in zoo.TABLE3.items():
            total = zoo.get(name).n_params
            assert 0.3 < t3["paper_params"] / total < 0.8


class TestHelpers:
    def test_layer_names_unique(self):
        for name in zoo.MODELS:
            names = zoo.get(name).layer_names()
            assert len(names) == len(set(names))

    def test_unknown_model_raises(self):
        with pytest.raises(KeyError):
            zoo.get("resnet50")

    def test_verify_report_lines(self):
        rows = zoo.verify_param_counts()
        assert len(rows) == 4
