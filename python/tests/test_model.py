"""L2 model graphs: shapes, BN folding, kernel-path vs oracle-path parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, model, zoo


@pytest.fixture(scope="module")
def svhn_setup():
    params = model.init_params("svhn", jax.random.PRNGKey(0))
    x, y = datasets.make_batch("svhn", 2, jax.random.PRNGKey(1))
    return params, x, y


class TestInitParams:
    @pytest.mark.parametrize("name", ["mnist", "cifar10", "svhn"])
    def test_param_shapes_match_spec(self, name):
        spec = zoo.get(name)
        params = model.init_params(name, jax.random.PRNGKey(0))
        n = 0
        for c in spec.convs:
            p = params[c.name]
            assert p["w"].shape == (c.kernel, c.kernel, c.in_ch, c.out_ch)
            n += p["w"].size + p["b"].size
        for f in spec.fcs:
            p = params[f.name]
            assert p["w"].shape == (f.in_dim, f.out_dim)
            n += p["w"].size + p["b"].size
        assert n == spec.n_params

    def test_deterministic(self):
        p1 = model.init_params("svhn", jax.random.PRNGKey(7))
        p2 = model.init_params("svhn", jax.random.PRNGKey(7))
        np.testing.assert_array_equal(
            np.asarray(p1["fc1792x272"]["w"]), np.asarray(p2["fc1792x272"]["w"])
        )


class TestForwardShapes:
    @pytest.mark.parametrize("name", ["mnist", "cifar10", "svhn"])
    def test_train_forward_logits(self, name):
        spec = zoo.get(name)
        params = model.init_params(name, jax.random.PRNGKey(0))
        x, _ = datasets.make_batch(name, 3, jax.random.PRNGKey(1))
        logits, newp = model.forward_train(name, params, x)
        assert logits.shape == (3, spec.n_classes)
        assert jnp.all(jnp.isfinite(logits))
        # BN running stats updated
        c0 = spec.convs[0].name
        assert not np.array_equal(
            np.asarray(newp[c0]["mu"]), np.asarray(params[c0]["mu"])
        )

    def test_deploy_forward_logits(self, svhn_setup):
        params, x, _ = svhn_setup
        folded = model.fold_bn(params)
        logits = model.forward_deploy("svhn", folded, x, use_kernel=False)
        assert logits.shape == (2, 10)


class TestFoldBn:
    def test_fold_matches_explicit_bn(self, svhn_setup):
        """Deploy path on folded params == conv + explicit BN (running stats)."""
        params, x, _ = svhn_setup
        # give the running stats non-trivial values
        p = {k: dict(v) for k, v in params.items()}
        c0 = zoo.get("svhn").convs[0].name
        p[c0]["mu"] = jnp.full_like(p[c0]["mu"], 0.3)
        p[c0]["var"] = jnp.full_like(p[c0]["var"], 2.0)
        folded = model.fold_bn(p)

        # manual: conv -> +b -> BN(running stats)
        y_manual = model._conv_xla(x, p[c0]["w"]) + p[c0]["b"]
        y_manual = (y_manual - p[c0]["mu"]) / jnp.sqrt(p[c0]["var"] + 1e-5)
        y_manual = y_manual * p[c0]["gamma"] + p[c0]["beta"]

        from compile.kernels import ref

        y_folded = ref.vdu_conv2d(
            x, folded[c0]["w"], folded[c0]["scale"], folded[c0]["bias"], act_bits=0
        )
        np.testing.assert_allclose(
            np.asarray(y_folded), np.asarray(y_manual), rtol=1e-4, atol=1e-4
        )

    def test_fc_layers_identity_scale(self, svhn_setup):
        params, _, _ = svhn_setup
        folded = model.fold_bn(params)
        f = folded["fc272x48"]
        np.testing.assert_array_equal(np.asarray(f["scale"]), 1.0)
        np.testing.assert_array_equal(np.asarray(f["bias"]), np.asarray(f["b"]))


class TestKernelPathParity:
    """The AOT'd kernel path must match the oracle path numerically."""

    @pytest.mark.parametrize("name", ["mnist", "svhn"])
    def test_kernel_vs_oracle_forward(self, name):
        params = model.init_params(name, jax.random.PRNGKey(3))
        folded = model.fold_bn(params)
        x, _ = datasets.make_batch(name, 1, jax.random.PRNGKey(4))
        a = model.forward_deploy(name, folded, x, use_kernel=True)
        b = model.forward_deploy(name, folded, x, use_kernel=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)

    def test_act_sparsity_collection(self):
        params = model.init_params("svhn", jax.random.PRNGKey(5))
        folded = model.fold_bn(params)
        x, _ = datasets.make_batch("svhn", 2, jax.random.PRNGKey(6))
        _, sp = model.forward_deploy(
            "svhn", folded, x, use_kernel=False, collect_act_sparsity=True
        )
        spec = zoo.get("svhn")
        assert sp.shape == (spec.n_conv_layers + spec.n_fc_layers,)
        # ReLU upstream => inner layers see real sparsity
        assert float(sp[1]) >= 0.0 and float(sp[-1]) > 0.05


class TestFlatParamList:
    def test_order_contract(self):
        """w, b, scale, bias per layer, in spec order — the AOT/SWT contract."""
        params = model.init_params("mnist", jax.random.PRNGKey(0))
        folded = model.fold_bn(params)
        flat = model.flat_param_list("mnist", folded)
        names = [n for n, _ in flat]
        spec = zoo.get("mnist")
        want = []
        for ln in spec.layer_names():
            want += [f"{ln}.w", f"{ln}.b", f"{ln}.scale", f"{ln}.bias"]
        assert names == want


class TestAccuracy:
    def test_random_model_near_chance(self):
        params = model.init_params("svhn", jax.random.PRNGKey(8))
        folded = model.fold_bn(params)
        acc = model.accuracy(
            "svhn", folded, datasets.eval_batches("svhn", 2, 16)
        )
        assert 0.0 <= acc <= 60.0  # untrained: near 10% chance
