"""Training loop: loss decreases, masks enforced, schedule honoured."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import sparsify, train, zoo


@pytest.fixture(scope="module")
def short_run():
    """One short sparsity-aware run on svhn shared across tests."""
    plan = sparsify.default_plan("svhn")
    cfg = train.TrainConfig(steps=30, batch=16, log_every=1000)
    params, masks, history = train.train("svhn", plan, cfg, log=lambda s: None)
    return plan, params, masks, history


class TestTraining:
    def test_loss_decreases(self, short_run):
        _, _, _, history = short_run
        first = np.mean(history[:5])
        last = np.mean(history[-5:])
        assert last < first * 0.8, (first, last)

    def test_masks_enforced_in_params(self, short_run):
        plan, params, masks, _ = short_run
        for ln in plan.layer_names:
            w = np.asarray(params[ln]["w"])
            m = np.asarray(masks[ln])
            assert (w[m == 0] == 0).all()

    def test_final_sparsity_reached(self, short_run):
        plan, params, _, _ = short_run
        rep = sparsify.sparsity_report(params)
        for ln, target in zip(plan.layer_names, plan.sparsity):
            assert rep[ln] >= target * 0.95, (ln, rep[ln], target)

    def test_unpruned_layers_stay_dense(self, short_run):
        plan, params, _, _ = short_run
        rep = sparsify.sparsity_report(params)
        for ln in zoo.get("svhn").layer_names():
            if ln not in plan.layer_names:
                assert rep[ln] < 0.01

    def test_params_finite(self, short_run):
        _, params, _, _ = short_run
        for p in params.values():
            for v in p.values():
                assert bool(jnp.all(jnp.isfinite(v)))


class TestEvaluate:
    def test_trained_beats_chance(self, short_run):
        _, params, _, _ = short_run
        acc = train.evaluate("svhn", params, n_batches=2, batch=32)
        assert acc > 30.0  # chance is 10%

    def test_kernel_path_evaluation_close(self, short_run):
        """Accuracy through the Pallas kernel path ~= oracle path."""
        _, params, _, _ = short_run
        a0 = train.evaluate("svhn", params, n_batches=1, batch=8, use_kernel=False)
        a1 = train.evaluate("svhn", params, n_batches=1, batch=8, use_kernel=True)
        assert abs(a0 - a1) <= 12.5  # one sample of 8 may flip
