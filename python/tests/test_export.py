"""Export path: SWT binary round-trip and descriptor integrity."""

import json
from pathlib import Path

import jax
import numpy as np
import pytest

from compile import cluster, export, model, sparsify, zoo


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    """Export a small (untrained) svhn model once for all tests."""
    outdir = tmp_path_factory.mktemp("art")
    params = model.init_params("svhn", jax.random.PRNGKey(0))
    masks = {
        "fc1792x272": sparsify.magnitude_mask(params["fc1792x272"]["w"], 0.5)
    }
    params = sparsify.apply_masks(params, masks)
    params, _ = cluster.cluster_params(params, 64)
    export.export_model(outdir, "svhn", params, 64, accuracy=12.5,
                        act_sparsity={"conv3x56": 0.25})
    return outdir, params


class TestSwtRoundTrip:
    def test_round_trip_exact(self, tmp_path):
        tensors = [
            ("a", np.arange(12, dtype=np.float32).reshape(3, 4)),
            ("b.scale", np.array([1.5], dtype=np.float32)),
            ("scalar-ish", np.float32(7.0).reshape(())),
        ]
        p = tmp_path / "t.swt"
        export.write_swt(p, tensors)
        back = export.read_swt(p)
        assert [n for n, _ in back] == [n for n, _ in tensors]
        for (_, a), (_, b) in zip(tensors, back):
            np.testing.assert_array_equal(np.asarray(a), b)

    def test_magic_guard(self, tmp_path):
        p = tmp_path / "bad.swt"
        p.write_bytes(b"NOPE" + b"\x00" * 16)
        with pytest.raises(AssertionError):
            export.read_swt(p)

    def test_model_export_order(self, exported):
        """SWT tensor order must equal the flat_param_list AOT contract."""
        outdir, params = exported
        folded = model.fold_bn(params)
        want = [n for n, _ in model.flat_param_list("svhn", folded)]
        got = [n for n, _ in export.read_swt(outdir / "svhn.swt")]
        assert got == want

    def test_model_export_values(self, exported):
        outdir, params = exported
        folded = model.fold_bn(params)
        flat = dict(model.flat_param_list("svhn", folded))
        back = dict(export.read_swt(outdir / "svhn.swt"))
        np.testing.assert_allclose(
            np.asarray(flat["conv3x56.w"]), back["conv3x56.w"], rtol=1e-6
        )


class TestDescriptor:
    def test_fields(self, exported):
        outdir, _ = exported
        desc = json.loads((outdir / "svhn.json").read_text())
        assert desc["model"] == "svhn"
        assert desc["n_clusters"] == 64
        assert desc["weight_dac_bits"] == 6
        assert desc["act_dac_bits"] == 16
        assert len(desc["layers"]) == 7  # 4 conv + 3 fc
        assert desc["paper"]["baseline_params"] == 552_362

    def test_layer_entries(self, exported):
        outdir, _ = exported
        desc = json.loads((outdir / "svhn.json").read_text())
        conv0 = desc["layers"][0]
        assert conv0["kind"] == "conv" and conv0["in_hw"] == 32
        assert conv0["act_sparsity"] == 0.25
        fc0 = desc["layers"][4]
        assert fc0["kind"] == "fc" and fc0["in_dim"] == 1792
        # the pruned layer reports ~0.5 weight sparsity
        assert 0.45 < fc0["weight_sparsity"] < 0.55

    def test_unique_weights_capped_by_clusters(self, exported):
        outdir, _ = exported
        desc = json.loads((outdir / "svhn.json").read_text())
        for l in desc["layers"]:
            assert l["unique_weights"] <= 64

    def test_surviving_params(self, exported):
        outdir, params = exported
        desc = json.loads((outdir / "svhn.json").read_text())
        assert desc["surviving_params"] == sparsify.surviving_params(params)
        assert desc["surviving_params"] < desc["total_params"]
