"""Synthetic dataset stand-ins: shapes, determinism, learnability signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, zoo


class TestMakeBatch:
    @pytest.mark.parametrize("name", list(zoo.MODELS))
    def test_shapes_and_dtypes(self, name):
        spec = zoo.get(name)
        x, y = datasets.make_batch(name, 4, jax.random.PRNGKey(0))
        assert x.shape == (4, spec.input_hw, spec.input_hw, spec.input_ch)
        assert x.dtype == jnp.float32
        assert y.shape == (4,) and y.dtype == jnp.int32

    def test_labels_in_range(self):
        _, y = datasets.make_batch("cifar10", 64, jax.random.PRNGKey(1))
        assert int(jnp.min(y)) >= 0 and int(jnp.max(y)) < 10

    def test_deterministic_same_key(self):
        x1, y1 = datasets.make_batch("svhn", 8, jax.random.PRNGKey(42))
        x2, y2 = datasets.make_batch("svhn", 8, jax.random.PRNGKey(42))
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    def test_different_keys_differ(self):
        x1, _ = datasets.make_batch("svhn", 8, jax.random.PRNGKey(0))
        x2, _ = datasets.make_batch("svhn", 8, jax.random.PRNGKey(1))
        assert not np.array_equal(np.asarray(x1), np.asarray(x2))


class TestTemplates:
    def test_template_shapes(self):
        t = datasets.class_templates("mnist")
        assert t.shape == (10, 28, 28, 1)

    def test_templates_distinct(self):
        """Classes must be separable: template cross-correlation << self."""
        t = np.asarray(datasets.class_templates("cifar10"))
        flat = t.reshape(10, -1)
        flat = flat / np.linalg.norm(flat, axis=1, keepdims=True)
        gram = flat @ flat.T
        off = gram - np.eye(10)
        assert np.abs(off).max() < 0.5

    def test_unit_scale(self):
        t = np.asarray(datasets.class_templates("svhn"))
        stds = t.reshape(10, -1).std(axis=1)
        np.testing.assert_allclose(stds, 1.0, atol=0.05)


class TestEvalStream:
    def test_deterministic_stream(self):
        s1 = [(np.asarray(x), np.asarray(y))
              for x, y in datasets.eval_batches("mnist", 2, 4)]
        s2 = [(np.asarray(x), np.asarray(y))
              for x, y in datasets.eval_batches("mnist", 2, 4)]
        for (x1, y1), (x2, y2) in zip(s1, s2):
            np.testing.assert_array_equal(x1, x2)
            np.testing.assert_array_equal(y1, y2)

    def test_count(self):
        assert len(list(datasets.eval_batches("mnist", 3, 2))) == 3


class TestLearnability:
    def test_nearest_template_classifies(self):
        """A trivial nearest-template classifier beats chance by a wide
        margin — the datasets carry real class signal for training."""
        t = np.asarray(datasets.class_templates("mnist")).reshape(10, -1)
        x, y = datasets.make_batch("mnist", 64, jax.random.PRNGKey(3))
        xf = np.asarray(x).reshape(64, -1)
        pred = np.argmax(xf @ t.T, axis=1)
        acc = (pred == np.asarray(y)).mean()
        assert acc > 0.5
